//! Checkpoint/resume: a versioned, deterministic snapshot codec for the
//! engine, plus crash-surviving drivers for single jobs
//! ([`run_job_with_recovery`]) and job streams
//! ([`super::tenancy::run_stream_with_recovery`]).
//!
//! ## Format
//!
//! A snapshot is one JSON document (hand-rolled codec in
//! [`crate::util::json`] — deterministic rendering, ordered object
//! fields, `f64` carried as 16-hex-digit IEEE-754 bit patterns so the
//! round trip is bit-exact, NaN and infinities included):
//!
//! ```text
//! { "format": "mrperf-snapshot", "version": 1, "kind": "job"|"stream",
//!   "compat": { ...shape of the run this snapshot belongs to... },
//!   "fluid":  { ...FluidSim dynamic state... },
//!   "exec":   { ...Executor dynamic state... } }       // kind = job
//! ```
//!
//! Three error classes are reported distinctly: **malformed** (not
//! parseable / not a snapshot), **version mismatch** (`version` ≠ what
//! this build reads), and **incompatible** (a well-formed snapshot of a
//! *different run* — topology shape, app, split count, config knobs).
//!
//! ## Semantics
//!
//! Snapshots are taken only at **event boundaries**: the executor's
//! event heap drained, so the heap contributes nothing but its clock,
//! and every in-flight transfer/compute lives in the fluid state
//! (referenced by activity id). The immutable run inputs — topology,
//! plan, app, config, input records — are *not* serialized; resume
//! reconstructs the executor from the same arguments (compat-probed by
//! the header) and overlays the dynamic state. The invariant, enforced
//! by tests/dynamics.rs: **resume(checkpoint(t)) finishes bit-identical
//! to the uninterrupted run** for every dynamics profile — every metric
//! equal to the bit, except `coordinator_restarts`, which counts the
//! crash/restart cycles survived (provenance, excluded from `sig()`).

use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::util::json::Json;

use super::executor::{Executor, JobResult, ResourceSet};
use super::fluid::{FluidActivityState, FluidSim, FluidState};
use super::job::{JobConfig, MapReduceApp, Record};
use super::metrics::JobMetrics;

/// Magic marker of every snapshot file.
pub const SNAPSHOT_FORMAT: &str = "mrperf-snapshot";
/// On-disk format version this build writes and reads.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Crash/checkpoint options for [`run_job_with_recovery`] (and the
/// stream variant). All default to off, in which case the driver is
/// bit-identical to [`super::executor::run_job`].
#[derive(Debug, Clone, Default)]
pub struct RecoveryOpts {
    /// Snapshot cadence in virtual seconds (checkpoints are taken at
    /// the first event boundary at or past each multiple). `None` = no
    /// checkpoints.
    pub checkpoint_every: Option<f64>,
    /// Simulated coordinator crash: at the first event boundary at or
    /// past this virtual time, the in-memory executor and fluid state
    /// are dropped and the run resumes from the latest checkpoint
    /// (cold restart if none was taken yet). Requires
    /// `checkpoint_every`.
    pub crash_at: Option<f64>,
    /// Persist each checkpoint to this file (and resume through the
    /// file, proving the on-disk round trip). `None` keeps snapshots
    /// in memory.
    pub checkpoint_path: Option<String>,
    /// Snapshot text to resume from instead of starting fresh. The
    /// caller must supply the same topology/plan/app/config/inputs the
    /// snapshot was taken under (compat-checked).
    pub resume_from: Option<String>,
}

impl RecoveryOpts {
    pub(crate) fn validate(&self) -> Result<(), String> {
        if let Some(t) = self.checkpoint_every {
            if !(t.is_finite() && t > 0.0) {
                return Err(format!("--checkpoint-every must be finite and > 0, got {t}"));
            }
        }
        if let Some(t) = self.crash_at {
            if !(t.is_finite() && t > 0.0) {
                return Err(format!("--crash-at must be finite and > 0, got {t}"));
            }
            if self.checkpoint_every.is_none() {
                return Err(
                    "--crash-at requires --checkpoint-every (without checkpoints the \
                     coordinator has nothing to resume from)"
                        .into(),
                );
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------------- header

/// Validate the snapshot envelope: format marker, version, kind.
pub(crate) fn check_header(doc: &Json, kind: &str) -> Result<(), String> {
    let format = doc
        .get("format")
        .ok_or_else(|| "malformed snapshot: missing `format` marker".to_string())?
        .as_str()
        .map_err(|e| format!("malformed snapshot: {e}"))?;
    if format != SNAPSHOT_FORMAT {
        return Err(format!(
            "malformed snapshot: format marker `{format}` (expected `{SNAPSHOT_FORMAT}`)"
        ));
    }
    let version = doc.field("version")?.as_u64()?;
    if version != SNAPSHOT_VERSION {
        return Err(format!(
            "snapshot version mismatch: file is v{version}, this build reads v{SNAPSHOT_VERSION}"
        ));
    }
    let k = doc.field("kind")?.as_str()?;
    if k != kind {
        return Err(format!("incompatible snapshot: kind `{k}`, expected `{kind}`"));
    }
    Ok(())
}

/// Compare a snapshot's `compat` object against the current run's
/// expected shape, field by field.
pub(crate) fn check_compat(expected: &[(String, Json)], got: &Json) -> Result<(), String> {
    for (key, want) in expected {
        let have = got
            .get(key)
            .ok_or_else(|| format!("incompatible snapshot: compat field `{key}` missing"))?;
        if have.render() != want.render() {
            return Err(format!(
                "incompatible snapshot: `{key}` is {} in the file but {} for this run",
                have.render(),
                want.render()
            ));
        }
    }
    Ok(())
}

/// The compatibility shape of a single-job run.
fn job_compat(
    topo: &Topology,
    config: &JobConfig,
    app: &dyn MapReduceApp,
    n_tasks: usize,
) -> Vec<(String, Json)> {
    vec![
        ("sources".into(), Json::uint(topo.n_sources())),
        ("mappers".into(), Json::uint(topo.n_mappers())),
        ("reducers".into(), Json::uint(topo.n_reducers())),
        ("tasks".into(), Json::uint(n_tasks)),
        ("buckets".into(), Json::uint(config.n_buckets)),
        ("split_size".into(), Json::uint(config.split_size)),
        ("max_attempts".into(), Json::uint(config.max_attempts as usize)),
        ("barriers".into(), Json::Str(config.barriers.label())),
        ("app".into(), Json::Str(app.name().into())),
        ("replan".into(), Json::Str(config.replan.label())),
    ]
}

// ----------------------------------------------------------- metrics

/// Serialize every [`JobMetrics`] field (floats bit-exact).
pub(crate) fn encode_metrics(m: &JobMetrics) -> Json {
    Json::Obj(vec![
        ("makespan".into(), Json::f64_bits(m.makespan)),
        ("push_end".into(), Json::f64_bits(m.push_end)),
        ("map_end".into(), Json::f64_bits(m.map_end)),
        ("shuffle_end".into(), Json::f64_bits(m.shuffle_end)),
        ("push_bytes".into(), Json::f64_bits(m.push_bytes)),
        ("shuffle_bytes".into(), Json::f64_bits(m.shuffle_bytes)),
        ("output_bytes".into(), Json::f64_bits(m.output_bytes)),
        ("n_map_tasks".into(), Json::uint(m.n_map_tasks)),
        ("n_reduce_tasks".into(), Json::uint(m.n_reduce_tasks)),
        ("spec_launched".into(), Json::uint(m.spec_launched)),
        ("spec_won".into(), Json::uint(m.spec_won)),
        ("stolen".into(), Json::uint(m.stolen)),
        ("dyn_events".into(), Json::uint(m.dyn_events)),
        ("failures_injected".into(), Json::uint(m.failures_injected)),
        ("tasks_requeued".into(), Json::uint(m.tasks_requeued)),
        ("reducers_failed".into(), Json::uint(m.reducers_failed)),
        ("reduce_ranges_reassigned".into(), Json::uint(m.reduce_ranges_reassigned)),
        ("reduce_bytes_replayed".into(), Json::f64_bits(m.reduce_bytes_replayed)),
        ("shuffle_bytes_delivered".into(), Json::f64_bits(m.shuffle_bytes_delivered)),
        ("sources_refreshed".into(), Json::uint(m.sources_refreshed)),
        ("push_bytes_repushed".into(), Json::f64_bits(m.push_bytes_repushed)),
        ("push_bytes_delivered".into(), Json::f64_bits(m.push_bytes_delivered)),
        ("input_records".into(), Json::uint(m.input_records)),
        ("intermediate_records".into(), Json::uint(m.intermediate_records)),
        ("output_records".into(), Json::uint(m.output_records)),
        ("ranges_dead_lettered".into(), Json::uint(m.ranges_dead_lettered)),
        ("splits_dead_lettered".into(), Json::uint(m.splits_dead_lettered)),
        ("dlq_bytes".into(), Json::f64_bits(m.dlq_bytes)),
        ("coordinator_restarts".into(), Json::uint(m.coordinator_restarts)),
        ("replans".into(), Json::uint(m.replans)),
        ("replans_skipped".into(), Json::uint(m.replans_skipped)),
        ("replan_migrated_splits".into(), Json::uint(m.replan_migrated_splits)),
        ("replan_migrated_ranges".into(), Json::uint(m.replan_migrated_ranges)),
        ("fluid_resolves".into(), Json::u64(m.fluid_resolves)),
        ("fluid_resources_touched".into(), Json::u64(m.fluid_resources_touched)),
    ])
}

/// Inverse of [`encode_metrics`]; every field required.
pub(crate) fn decode_metrics(j: &Json) -> Result<JobMetrics, String> {
    Ok(JobMetrics {
        makespan: j.field("makespan")?.as_f64_bits()?,
        push_end: j.field("push_end")?.as_f64_bits()?,
        map_end: j.field("map_end")?.as_f64_bits()?,
        shuffle_end: j.field("shuffle_end")?.as_f64_bits()?,
        push_bytes: j.field("push_bytes")?.as_f64_bits()?,
        shuffle_bytes: j.field("shuffle_bytes")?.as_f64_bits()?,
        output_bytes: j.field("output_bytes")?.as_f64_bits()?,
        n_map_tasks: j.field("n_map_tasks")?.as_usize()?,
        n_reduce_tasks: j.field("n_reduce_tasks")?.as_usize()?,
        spec_launched: j.field("spec_launched")?.as_usize()?,
        spec_won: j.field("spec_won")?.as_usize()?,
        stolen: j.field("stolen")?.as_usize()?,
        dyn_events: j.field("dyn_events")?.as_usize()?,
        failures_injected: j.field("failures_injected")?.as_usize()?,
        tasks_requeued: j.field("tasks_requeued")?.as_usize()?,
        reducers_failed: j.field("reducers_failed")?.as_usize()?,
        reduce_ranges_reassigned: j.field("reduce_ranges_reassigned")?.as_usize()?,
        reduce_bytes_replayed: j.field("reduce_bytes_replayed")?.as_f64_bits()?,
        shuffle_bytes_delivered: j.field("shuffle_bytes_delivered")?.as_f64_bits()?,
        sources_refreshed: j.field("sources_refreshed")?.as_usize()?,
        push_bytes_repushed: j.field("push_bytes_repushed")?.as_f64_bits()?,
        push_bytes_delivered: j.field("push_bytes_delivered")?.as_f64_bits()?,
        input_records: j.field("input_records")?.as_usize()?,
        intermediate_records: j.field("intermediate_records")?.as_usize()?,
        output_records: j.field("output_records")?.as_usize()?,
        ranges_dead_lettered: j.field("ranges_dead_lettered")?.as_usize()?,
        splits_dead_lettered: j.field("splits_dead_lettered")?.as_usize()?,
        dlq_bytes: j.field("dlq_bytes")?.as_f64_bits()?,
        coordinator_restarts: j.field("coordinator_restarts")?.as_usize()?,
        replans: j.field("replans")?.as_usize()?,
        replans_skipped: j.field("replans_skipped")?.as_usize()?,
        replan_migrated_splits: j.field("replan_migrated_splits")?.as_usize()?,
        replan_migrated_ranges: j.field("replan_migrated_ranges")?.as_usize()?,
        fluid_resolves: j.field("fluid_resolves")?.as_u64()?,
        fluid_resources_touched: j.field("fluid_resources_touched")?.as_u64()?,
    })
}

// ------------------------------------------------------------- fluid

/// Serialize an exported [`FluidState`] (floats bit-exact; the `active`
/// list order and stale entries preserved verbatim — component
/// numbering depends on them).
pub(crate) fn encode_fluid(st: &FluidState) -> Json {
    let uints = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::uint(x)).collect());
    Json::Obj(vec![
        ("now".into(), Json::f64_bits(st.now)),
        ("threads".into(), Json::uint(st.threads)),
        (
            "capacities".into(),
            Json::Arr(st.capacities.iter().map(|&c| Json::f64_bits(c)).collect()),
        ),
        (
            "activities".into(),
            Json::Arr(
                st.activities
                    .iter()
                    .map(|a| {
                        Json::Obj(vec![
                            ("rem".into(), Json::f64_bits(a.remaining)),
                            ("res".into(), uints(&a.resources)),
                            ("done".into(), Json::Bool(a.done)),
                            ("rate".into(), Json::f64_bits(a.rate)),
                            ("tag".into(), Json::u64(a.tag)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("active".into(), uints(&st.active)),
        ("dirty".into(), Json::Bool(st.dirty)),
        ("dirty_res".into(), uints(&st.dirty_res)),
        ("n_resolves".into(), Json::u64(st.n_resolves)),
        ("n_resources_touched".into(), Json::u64(st.n_resources_touched)),
    ])
}

/// Inverse of [`encode_fluid`]. Structural validation (dangling ids,
/// negative remaining work) happens in [`FluidSim::from_state`].
pub(crate) fn decode_fluid(j: &Json) -> Result<FluidState, String> {
    let uints = |j: &Json| -> Result<Vec<usize>, String> {
        j.as_arr()?.iter().map(|v| v.as_usize()).collect()
    };
    let activities = j
        .field("activities")?
        .as_arr()?
        .iter()
        .map(|a| -> Result<FluidActivityState, String> {
            Ok(FluidActivityState {
                remaining: a.field("rem")?.as_f64_bits()?,
                resources: uints(a.field("res")?)?,
                done: a.field("done")?.as_bool()?,
                rate: a.field("rate")?.as_f64_bits()?,
                tag: a.field("tag")?.as_u64()?,
            })
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FluidState {
        now: j.field("now")?.as_f64_bits()?,
        threads: j.field("threads")?.as_usize()?.max(1),
        capacities: j
            .field("capacities")?
            .as_arr()?
            .iter()
            .map(|c| c.as_f64_bits())
            .collect::<Result<_, _>>()?,
        activities,
        active: uints(j.field("active")?)?,
        dirty: j.field("dirty")?.as_bool()?,
        dirty_res: uints(j.field("dirty_res")?)?,
        n_resolves: j.field("n_resolves")?.as_u64()?,
        n_resources_touched: j.field("n_resources_touched")?.as_u64()?,
    })
}

// ----------------------------------------------------- job snapshots

/// Serialize one single-job run at an event boundary.
fn snapshot_job(
    exec: &Executor,
    sim: &FluidSim,
    topo: &Topology,
    config: &JobConfig,
    app: &dyn MapReduceApp,
) -> Json {
    Json::Obj(vec![
        ("format".into(), Json::Str(SNAPSHOT_FORMAT.into())),
        ("version".into(), Json::u64(SNAPSHOT_VERSION)),
        ("kind".into(), Json::Str("job".into())),
        ("compat".into(), Json::Obj(job_compat(topo, config, app, exec.n_tasks()))),
        ("fluid".into(), encode_fluid(&sim.export_state())),
        ("exec".into(), exec.encode_state()),
    ])
}

/// Run one job with optional checkpointing, a simulated coordinator
/// crash, and/or resume from an existing snapshot. With all
/// [`RecoveryOpts`] off this follows exactly the same call sequence as
/// [`super::executor::run_job`] and is bit-identical to it. The final
/// metrics carry `coordinator_restarts` = crash/restart cycles
/// survived; every other field is bit-identical to the uninterrupted
/// run.
pub fn run_job_with_recovery(
    topo: &Topology,
    plan: &Plan,
    app: &dyn MapReduceApp,
    config: &JobConfig,
    inputs: &[Vec<Record>],
    opts: &RecoveryOpts,
) -> Result<JobResult, String> {
    opts.validate()?;
    let mut snapshot_text: Option<String> = opts.resume_from.clone();
    let mut crash_pending = opts.crash_at;
    let mut restarts = 0usize;

    loop {
        // Materialize the coordinator: fresh, or overlaid from the
        // latest snapshot (resume reconstructs the executor from the
        // same immutable arguments, then restores the dynamic state).
        let mut sim;
        let mut exec;
        match &snapshot_text {
            Some(text) => {
                let doc = Json::parse(text).map_err(|e| format!("malformed snapshot: {e}"))?;
                check_header(&doc, "job")?;
                let fluid = decode_fluid(doc.field("fluid")?)?;
                sim = FluidSim::from_state(&fluid)?;
                exec = Executor::new(
                    topo,
                    plan,
                    app,
                    config,
                    inputs,
                    ResourceSet::layout(topo),
                    config.dynamics.as_ref(),
                    0,
                    1.0,
                );
                check_compat(
                    &job_compat(topo, config, app, exec.n_tasks()),
                    doc.field("compat")?,
                )?;
                exec.restore_state(doc.field("exec")?, fluid.activities.len())?;
                // Re-evaluate the replan policy against the restored
                // effective platform. The restored baseline matches it
                // (accepting a replan updates the baseline before the
                // next checkpoint), so hysteresis declines and the
                // evaluation lands in `replans_skipped` — provenance,
                // like `coordinator_restarts` — keeping resumed runs
                // bit-identical in every sig() field.
                exec.replan_on_resume(&mut sim);
            }
            None => {
                sim = FluidSim::new();
                sim.set_threads(config.threads.max(1));
                let res = ResourceSet::build(&mut sim, topo);
                exec = Executor::new(
                    topo,
                    plan,
                    app,
                    config,
                    inputs,
                    res,
                    config.dynamics.as_ref(),
                    0,
                    1.0,
                );
                exec.start(&mut sim);
            }
        }
        // Checkpoint cadence: the first multiple of the interval
        // strictly past the current clock (so a resumed run does not
        // immediately re-checkpoint its own resume point).
        let mut next_ckpt = opts.checkpoint_every.map(|every| {
            let mut t = every;
            while t <= sim.now() {
                t += every;
            }
            t
        });

        // Main loop — the body of `run_job`, with crash and checkpoint
        // hooks at the top of each iteration (an event boundary: the
        // event heap is drained there). Crash is checked first: a
        // checkpoint due at the crash instant is lost with the
        // coordinator, exactly like a real crash racing its timer.
        let mut crashed = false;
        loop {
            if let Some(t2) = crash_pending {
                if sim.now() >= t2 {
                    crash_pending = None;
                    restarts += 1;
                    crashed = true;
                    break;
                }
            }
            if let (Some(every), Some(next)) = (opts.checkpoint_every, next_ckpt.as_mut()) {
                while sim.now() >= *next {
                    let text = snapshot_job(&exec, &sim, topo, config, app).render();
                    if let Some(path) = &opts.checkpoint_path {
                        std::fs::write(path, &text)
                            .map_err(|e| format!("cannot write checkpoint `{path}`: {e}"))?;
                    }
                    snapshot_text = Some(text);
                    *next += every;
                }
            }

            let step = match exec.next_dyn_time() {
                Some(tt) if sim.active_count() > 0 => sim.step_until(tt),
                Some(tt) => {
                    if exec.is_complete() {
                        break;
                    }
                    sim.jump_to(tt);
                    Some((sim.now(), Vec::new()))
                }
                None => sim.step(),
            };
            let Some((now, completed)) = step else { break };
            if completed.is_empty() {
                exec.apply_dynamics(&mut sim);
                continue;
            }
            for aid in completed {
                exec.enqueue(now, aid);
            }
            exec.drain(&mut sim);
            exec.maybe_speculate(&mut sim);
        }
        if crashed {
            // Drop the in-memory coordinator; the next iteration
            // resumes from the latest snapshot — through the file when
            // one is configured — or restarts cold if none was taken.
            if let Some(path) = &opts.checkpoint_path {
                if snapshot_text.is_some() {
                    snapshot_text = Some(
                        std::fs::read_to_string(path)
                            .map_err(|e| format!("cannot read checkpoint `{path}`: {e}"))?,
                    );
                }
            }
            continue;
        }
        let mut result = exec.into_result();
        result.metrics.fluid_resolves = sim.resolves();
        result.metrics.fluid_resources_touched = sim.resources_touched();
        result.metrics.coordinator_restarts = restarts;
        return Ok(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::executor::{run_job, JobOutcome};
    use crate::engine::job::Record;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;

    struct Identity;
    impl MapReduceApp for Identity {
        fn name(&self) -> &'static str {
            "identity"
        }
        fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
            emit(record.clone());
        }
        fn reduce(&self, _group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
            for r in records {
                emit(r.clone());
            }
        }
    }

    fn inputs(topo: &Topology, per_source: usize) -> Vec<Vec<Record>> {
        (0..topo.n_sources())
            .map(|i| {
                (0..per_source)
                    .map(|n| Record::new(format!("key-{i}-{n}"), format!("value-{n}")))
                    .collect()
            })
            .collect()
    }

    fn sig(m: &JobMetrics) -> String {
        format!(
            "{:016x} {:016x} {:016x} {:016x} {} {} {} {} {:016x}",
            m.makespan.to_bits(),
            m.push_bytes.to_bits(),
            m.shuffle_bytes.to_bits(),
            m.shuffle_bytes_delivered.to_bits(),
            m.output_records,
            m.tasks_requeued,
            m.ranges_dead_lettered,
            m.splits_dead_lettered,
            m.dlq_bytes.to_bits(),
        )
    }

    #[test]
    fn metrics_round_trip_is_bit_exact() {
        let m = JobMetrics {
            makespan: 123.456789,
            push_end: f64::NAN,
            shuffle_bytes: 1.0e9 + 3.0,
            dlq_bytes: 0.1 + 0.2, // deliberately not 0.3
            fluid_resolves: 987654321,
            n_map_tasks: 42,
            coordinator_restarts: 3,
            ..Default::default()
        };
        let back = decode_metrics(&encode_metrics(&m)).unwrap();
        assert_eq!(back.makespan.to_bits(), m.makespan.to_bits());
        assert_eq!(back.push_end.to_bits(), m.push_end.to_bits(), "NaN survives");
        assert_eq!(back.shuffle_bytes.to_bits(), m.shuffle_bytes.to_bits());
        assert_eq!(back.dlq_bytes.to_bits(), m.dlq_bytes.to_bits());
        assert_eq!(back.fluid_resolves, m.fluid_resolves);
        assert_eq!(back.n_map_tasks, 42);
        assert_eq!(back.coordinator_restarts, 3);
    }

    #[test]
    fn decode_metrics_requires_every_field() {
        let mut obj = match encode_metrics(&JobMetrics::default()) {
            Json::Obj(fields) => fields,
            _ => unreachable!(),
        };
        obj.retain(|(k, _)| k != "dlq_bytes");
        let e = decode_metrics(&Json::Obj(obj)).unwrap_err();
        assert!(e.contains("dlq_bytes"), "{e}");
    }

    #[test]
    fn header_rejects_foreign_and_future_files() {
        let doc = |format: &str, version: u64, kind: &str| {
            Json::Obj(vec![
                ("format".into(), Json::Str(format.into())),
                ("version".into(), Json::u64(version)),
                ("kind".into(), Json::Str(kind.into())),
            ])
        };
        let e = check_header(&doc("not-a-snapshot", 1, "job"), "job").unwrap_err();
        assert!(e.contains("malformed"), "{e}");
        let e = check_header(&doc(SNAPSHOT_FORMAT, 99, "job"), "job").unwrap_err();
        assert!(e.contains("version mismatch") && e.contains("v99"), "{e}");
        let e = check_header(&doc(SNAPSHOT_FORMAT, 1, "stream"), "job").unwrap_err();
        assert!(e.contains("kind"), "{e}");
        let e = check_header(&Json::Obj(vec![]), "job").unwrap_err();
        assert!(e.contains("malformed"), "{e}");
        check_header(&doc(SNAPSHOT_FORMAT, 1, "job"), "job").unwrap();
    }

    #[test]
    fn compat_mismatch_names_the_field() {
        let expected = vec![("mappers".to_string(), Json::uint(4))];
        let got = Json::Obj(vec![("mappers".into(), Json::uint(8))]);
        let e = check_compat(&expected, &got).unwrap_err();
        assert!(e.contains("incompatible") && e.contains("mappers"), "{e}");
        check_compat(&expected, &Json::Obj(vec![("mappers".into(), Json::uint(4))])).unwrap();
    }

    #[test]
    fn recovery_with_no_options_matches_run_job_bitwise() {
        let topo = example_1_3(60.0 * MB, 8.0 * MB, 60.0 * MB);
        let plan = Plan::local_push(&topo);
        let config = JobConfig::default();
        let ins = inputs(&topo, 120);
        let a = run_job(&topo, &plan, &Identity, &config, &ins);
        let b = run_job_with_recovery(
            &topo,
            &plan,
            &Identity,
            &config,
            &ins,
            &RecoveryOpts::default(),
        )
        .unwrap();
        assert_eq!(sig(&a.metrics), sig(&b.metrics));
        assert_eq!(a.metrics.fluid_resolves, b.metrics.fluid_resolves);
        assert_eq!(b.metrics.coordinator_restarts, 0);
        assert_eq!(b.outcome, JobOutcome::Complete);
    }

    #[test]
    fn crash_and_resume_is_bit_identical_to_uninterrupted() {
        let topo = example_1_3(60.0 * MB, 8.0 * MB, 60.0 * MB);
        let plan = Plan::local_push(&topo);
        let config = JobConfig::default();
        let ins = inputs(&topo, 200);
        let base = run_job(&topo, &plan, &Identity, &config, &ins);
        let horizon = base.metrics.makespan;
        assert!(horizon > 0.0);
        for (ck_frac, crash_frac) in [(0.2, 0.55), (0.1, 0.35), (0.4, 0.5)] {
            let opts = RecoveryOpts {
                checkpoint_every: Some(horizon * ck_frac),
                crash_at: Some(horizon * crash_frac),
                ..Default::default()
            };
            let got =
                run_job_with_recovery(&topo, &plan, &Identity, &config, &ins, &opts).unwrap();
            assert_eq!(
                sig(&got.metrics),
                sig(&base.metrics),
                "ck={ck_frac} crash={crash_frac}"
            );
            assert_eq!(got.metrics.fluid_resolves, base.metrics.fluid_resolves);
            assert_eq!(got.metrics.coordinator_restarts, 1);
            assert_eq!(got.outputs, base.outputs, "outputs identical after resume");
        }
    }

    #[test]
    fn crash_before_first_checkpoint_restarts_cold() {
        let topo = example_1_3(60.0 * MB, 8.0 * MB, 60.0 * MB);
        let plan = Plan::local_push(&topo);
        let config = JobConfig::default();
        let ins = inputs(&topo, 120);
        let base = run_job(&topo, &plan, &Identity, &config, &ins);
        let horizon = base.metrics.makespan;
        let opts = RecoveryOpts {
            // First checkpoint would land past the crash: nothing to
            // resume from, so the coordinator restarts from scratch.
            checkpoint_every: Some(horizon * 10.0),
            crash_at: Some(horizon * 0.5),
            ..Default::default()
        };
        let got = run_job_with_recovery(&topo, &plan, &Identity, &config, &ins, &opts).unwrap();
        assert_eq!(sig(&got.metrics), sig(&base.metrics));
        assert_eq!(got.metrics.coordinator_restarts, 1);
    }

    #[test]
    fn recovery_rejects_bad_options_and_bad_snapshots() {
        let topo = example_1_3(60.0 * MB, 8.0 * MB, 60.0 * MB);
        let plan = Plan::local_push(&topo);
        let config = JobConfig::default();
        let ins = inputs(&topo, 40);
        let run = |opts: &RecoveryOpts| {
            run_job_with_recovery(&topo, &plan, &Identity, &config, &ins, opts)
        };
        let e = run(&RecoveryOpts { crash_at: Some(5.0), ..Default::default() }).unwrap_err();
        assert!(e.contains("--crash-at requires --checkpoint-every"), "{e}");
        let e = run(&RecoveryOpts { checkpoint_every: Some(0.0), ..Default::default() })
            .unwrap_err();
        assert!(e.contains("--checkpoint-every"), "{e}");
        let e = run(&RecoveryOpts {
            resume_from: Some("this is not json".into()),
            ..Default::default()
        })
        .unwrap_err();
        assert!(e.contains("malformed snapshot"), "{e}");
        let future = format!(
            "{{\"format\":\"{SNAPSHOT_FORMAT}\",\"version\":2,\"kind\":\"job\"}}"
        );
        let e = run(&RecoveryOpts { resume_from: Some(future), ..Default::default() })
            .unwrap_err();
        assert!(e.contains("version mismatch"), "{e}");
    }

    #[test]
    fn resume_from_rejects_a_snapshot_of_a_different_run() {
        let topo = example_1_3(60.0 * MB, 8.0 * MB, 60.0 * MB);
        let plan = Plan::local_push(&topo);
        let config = JobConfig::default();
        let ins = inputs(&topo, 120);
        let base = run_job(&topo, &plan, &Identity, &config, &ins);
        let horizon = base.metrics.makespan;
        let dir = std::env::temp_dir().join("mrperf-snapshot-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        let opts = RecoveryOpts {
            checkpoint_every: Some(horizon * 0.3),
            checkpoint_path: Some(path.to_string_lossy().into_owned()),
            ..Default::default()
        };
        run_job_with_recovery(&topo, &plan, &Identity, &config, &ins, &opts).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Same snapshot, different config: compat must refuse it.
        let other = JobConfig { n_buckets: 128, ..JobConfig::default() };
        let e = run_job_with_recovery(
            &topo,
            &plan,
            &Identity,
            &other,
            &ins,
            &RecoveryOpts { resume_from: Some(text.clone()), ..Default::default() },
        )
        .unwrap_err();
        assert!(e.contains("incompatible") && e.contains("buckets"), "{e}");
        // Unmodified, it resumes and finishes bit-identically.
        let got = run_job_with_recovery(
            &topo,
            &plan,
            &Identity,
            &config,
            &ins,
            &RecoveryOpts { resume_from: Some(text), ..Default::default() },
        )
        .unwrap();
        assert_eq!(sig(&got.metrics), sig(&base.metrics));
        std::fs::remove_file(&path).ok();
    }
}
