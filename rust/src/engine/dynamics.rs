//! Dynamics & fault-injection: seeded, deterministic scenario traces.
//!
//! The paper's model (and our engine so far) assumes static link
//! bandwidths and reliable nodes, yet its own motivation — geo-distributed
//! data behind wide-area links — is exactly where bandwidth fluctuates and
//! nodes fail (Dolev et al., arXiv:1707.01869; Ceesay et al.,
//! arXiv:2005.11608 both single out WAN variability as the dominant
//! unmodelled effect). A [`ScenarioTrace`] closes that gap: it is a
//! pre-generated, time-sorted list of [`DynEvent`]s that the executor
//! injects into its virtual timeline:
//!
//! * **bandwidth changes** — inter-cluster link capacities re-scaled
//!   relative to their topology base values; the fluid simulation
//!   re-solves its max-min allocation at the event boundary;
//! * **node failures / recoveries** — a mapper drops out (running work is
//!   lost and re-queued, no new placements) and later returns; a reducer
//!   drops out (in-flight shuffle transfers and partial reduce work are
//!   lost; its outstanding key range either waits for recovery under
//!   strict plan enforcement or is adopted by a surviving reducer when
//!   the scheduler allows re-partitioning — see the reducer-failure
//!   lifecycle below) and later returns;
//! * **compute-slowdown stragglers** — a node's compute capacity scaled
//!   down and later restored (the §4.6.4 speculation trigger, now
//!   reproducible instead of emergent);
//! * **data staleness** — a source refreshes a fraction of its data
//!   mid-push ([`DynEvent::SourceRefresh`]): copies already on the wire
//!   or already delivered for splits that have not sealed yet are stale
//!   and must be re-pushed (see the staleness lifecycle below).
//!
//! ## Staleness lifecycle
//!
//! [`DynEvent::SourceRefresh`] re-dirties `fraction` of `source`'s data
//! at its firing time. The executor walks the source's push transfers in
//! creation order and re-dirties transfers feeding *un-sealed* splits
//! until the refreshed byte volume is covered:
//!
//! 1. a transfer still on the wire is cancelled and restarted from byte
//!    zero (the half-written copy is stale);
//! 2. a transfer already delivered is discarded at the mapper: its bytes
//!    are de-credited from `metrics.push_bytes_delivered` and the
//!    split's push gate re-opens;
//! 3. every re-send is counted in `metrics.push_bytes_repushed` (the
//!    staleness analogue of `reduce_bytes_replayed`), and a refresh that
//!    re-dirtied at least one transfer bumps `metrics.sources_refreshed`.
//!
//! Once every part of a split has arrived and the push barrier released
//! it, the split is *sealed*: the map task consumed a consistent
//! snapshot, and a later refresh of its source creates a new version
//! this job never observes (HDFS-style immutable inputs). At job end
//! `push_bytes_delivered == push_bytes` exactly — the same integer-exact
//! byte-conservation invariant the restartable reduce maintains for the
//! shuffle.
//!
//! ## Reducer-failure lifecycle
//!
//! [`DynEvent::ReducerFail`] kills reducer `k` at its firing time:
//!
//! 1. the executor cancels `k`'s in-flight shuffle transfers and any
//!    running reduce compute deterministically (sorted `ActivityId`
//!    order — hash-map iteration order must never leak into the
//!    simulation);
//! 2. shuffle bytes already delivered to `k` for key ranges it has not
//!    finished reducing are *lost* (the node's local disk died with it)
//!    and de-credited;
//! 3. the [`Scheduler`](super::scheduler::Scheduler) is asked, per
//!    outstanding key range, for a surviving reducer to adopt it
//!    (`reassign_reduce`). Plan-enforcing policies decline — the range
//!    waits for [`DynEvent::ReducerRecover`] — while the dynamic
//!    policies pick a survivor (same-cluster first in locality mode);
//! 4. lost transfers are replayed from their originating mappers (map
//!    outputs are durable until job end, as in Hadoop) to the range's
//!    current owner, counted in `metrics.reduce_bytes_replayed`, and the
//!    adopted range's reduce re-executes from scratch on the new node.
//!
//! [`DynEvent::ReducerRecover`] restores the node with all reduce slots
//! free and replays whatever held transfers still target ranges it owns.
//! Mapper-style last-writer-wins semantics apply: double failures are
//! idempotent, recovery of an up node is a no-op.
//!
//! Everything is generated from a `(profile, seed)` pair over a
//! [`TraceShape`] snapshot of the platform, so runs are reproducible
//! bit-for-bit: same seed → same trace → same metrics. A trace with zero
//! events leaves the engine's arithmetic untouched (the executor's fast
//! path is byte-identical to the static engine — property-tested in
//! tests/dynamics.rs).
//!
//! Scale factors are *absolute with respect to the topology base value*
//! (never cumulative), so overlapping windows compose last-writer-wins
//! and a final `factor = 1.0` event always restores the static platform.
//!
//! # Example
//!
//! Traces are reproducible bit-for-bit from a `(profile, seed)` pair:
//!
//! ```
//! use mrperf::engine::dynamics::{DynProfile, ScenarioTrace, TraceShape};
//! use mrperf::platform::{build_env, EnvKind};
//!
//! let topo = build_env(EnvKind::Global8);
//! let shape = TraceShape::of(&topo, 120.0); // horizon: expected makespan
//! let a = ScenarioTrace::generate(DynProfile::Failures, 7, &shape);
//! let b = ScenarioTrace::generate(DynProfile::Failures, 7, &shape);
//! assert_eq!(a, b);          // same seed → same trace
//! assert!(!a.is_empty());    // every profile emits events
//! let c = ScenarioTrace::generate(DynProfile::Staleness, 7, &shape);
//! assert_ne!(a.events(), c.events());
//! ```

use crate::platform::Topology;
use crate::util::rng::{Pcg64, Zipf};

/// Smallest allowed bandwidth/compute scale factor. Keeps every resource
/// capacity strictly positive so the fluid simulation cannot starve an
/// activity into a zero-rate deadlock.
pub const MIN_FACTOR: f64 = 0.02;

/// One injected platform change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DynEvent {
    /// Scale every inter-cluster (WAN) link to `factor` × its base
    /// bandwidth. Intra-cluster (LAN) links are never touched.
    WanScale { factor: f64 },
    /// Scale the inter-cluster links touching `cluster` (either endpoint)
    /// to `factor` × base.
    ClusterLinkScale { cluster: usize, factor: f64 },
    /// Mapper `node` fails: running map work there is lost and re-queued,
    /// and no new tasks are placed on it until it recovers.
    MapperFail { node: usize },
    /// Mapper `node` recovers with all its slots free.
    MapperRecover { node: usize },
    /// Reducer `node` fails: in-flight shuffle transfers and partial
    /// reduce work there are lost; its outstanding key ranges wait for
    /// recovery or are adopted by survivors (see the module docs).
    ReducerFail { node: usize },
    /// Reducer `node` recovers with all reduce slots free.
    ReducerRecover { node: usize },
    /// Scale mapper `node`'s compute capacity to `factor` × base
    /// (a straggler while `factor < 1`).
    MapperSlowdown { node: usize, factor: f64 },
    /// Scale reducer `node`'s compute capacity to `factor` × base.
    ReducerSlowdown { node: usize, factor: f64 },
    /// Source `source` refreshes `fraction` of its data mid-job: push
    /// transfers feeding splits that have not sealed yet carry stale
    /// bytes and must be re-sent (see the staleness lifecycle in the
    /// module docs). `fraction` must be in `(0, 1]`.
    SourceRefresh { source: usize, fraction: f64 },
}

/// A [`DynEvent`] stamped with its virtual firing time (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedEvent {
    pub time: f64,
    pub event: DynEvent,
}

/// The built-in scenario generators, selected on the CLI as
/// `--dynamics PROFILE[:SEED]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DynProfile {
    /// One step: WAN bandwidth drops mid-run, recovers later.
    Step,
    /// Square-wave (diurnal-style) WAN oscillation.
    Periodic,
    /// Zipf-burst: bursts hit Zipf-popular clusters — a hard link
    /// degradation, usually with a correlated node outage in the bursted
    /// cluster (a WAN incident takes machines with it).
    Burst,
    /// Node failure/recovery windows only: early mapper outages plus
    /// mid-run outages of the most attractive reducers.
    Failures,
    /// Compute-slowdown windows only.
    Stragglers,
    /// Burst + failures + stragglers combined.
    Churn,
    /// Correlated data staleness: Zipf-popular sources refresh fractions
    /// of their data early in the run, forcing re-pushes of splits whose
    /// data was still in flight or not yet sealed.
    Staleness,
}

impl DynProfile {
    pub fn all() -> [DynProfile; 7] {
        [
            DynProfile::Step,
            DynProfile::Periodic,
            DynProfile::Burst,
            DynProfile::Failures,
            DynProfile::Stragglers,
            DynProfile::Churn,
            DynProfile::Staleness,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            DynProfile::Step => "step",
            DynProfile::Periodic => "periodic",
            DynProfile::Burst => "burst",
            DynProfile::Failures => "failures",
            DynProfile::Stragglers => "stragglers",
            DynProfile::Churn => "churn",
            DynProfile::Staleness => "staleness",
        }
    }
}

/// Default trace seed when `--dynamics PROFILE` omits `:SEED`.
pub const DEFAULT_TRACE_SEED: u64 = 7;

/// Parse a CLI dynamics spec `PROFILE[:SEED]` (e.g. `burst`, `burst:7`).
pub fn parse_spec(spec: &str) -> Result<(DynProfile, u64), String> {
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.is_empty() || parts.len() > 2 {
        return Err(format!("bad dynamics spec '{spec}' (want PROFILE[:SEED])"));
    }
    let profile = DynProfile::all()
        .into_iter()
        .find(|p| p.label() == parts[0])
        .ok_or_else(|| {
            format!(
                "unknown dynamics profile '{}' (step | periodic | burst | failures | \
                 stragglers | churn | staleness)",
                parts[0]
            )
        })?;
    let seed = if parts.len() == 2 {
        parts[1].parse().map_err(|_| format!("bad dynamics seed '{}'", parts[1]))?
    } else {
        DEFAULT_TRACE_SEED
    };
    Ok((profile, seed))
}

/// The platform snapshot a generator needs: the job's expected timescale
/// plus how many clusters/nodes exist and where the mappers live.
#[derive(Debug, Clone)]
pub struct TraceShape {
    /// Expected job duration (seconds); event times are drawn as
    /// fractions of it. Any deterministic estimate works (e.g. the
    /// model-predicted or a measured static makespan).
    pub horizon: f64,
    pub n_clusters: usize,
    /// Cluster of each mapper node (`mapper_cluster[j]`).
    pub mapper_cluster: Vec<usize>,
    /// Number of data sources (staleness profiles draw refresh victims
    /// from these).
    pub n_sources: usize,
    pub n_reducers: usize,
    /// Reducer indices in descending *attractiveness* (compute capacity
    /// × aggregate incoming shuffle bandwidth). Failure profiles draw
    /// reducer victims from the top of this ranking: the best-provisioned,
    /// best-connected nodes are exactly where load-seeking plans
    /// concentrate the shuffle, so outages there are the ones a
    /// failure-aware plan must hedge against.
    pub reducer_rank: Vec<usize>,
}

impl TraceShape {
    pub fn of(topo: &Topology, horizon: f64) -> TraceShape {
        let r = topo.n_reducers();
        let attract: Vec<f64> = (0..r)
            .map(|k| {
                topo.c_red[k]
                    * (0..topo.n_mappers()).map(|j| topo.b_mr.get(j, k)).sum::<f64>()
            })
            .collect();
        let mut reducer_rank: Vec<usize> = (0..r).collect();
        // total_cmp (descending): degenerate capacities must not panic.
        reducer_rank.sort_by(|&a, &b| attract[b].total_cmp(&attract[a]).then(a.cmp(&b)));
        TraceShape {
            horizon,
            n_clusters: topo.clusters.len(),
            mapper_cluster: topo.mapper_cluster.clone(),
            n_sources: topo.n_sources(),
            n_reducers: r,
            reducer_rank,
        }
    }

    fn n_mappers(&self) -> usize {
        self.mapper_cluster.len()
    }

    /// Mapper indices living in `cluster`.
    fn mappers_in(&self, cluster: usize) -> Vec<usize> {
        (0..self.n_mappers()).filter(|&j| self.mapper_cluster[j] == cluster).collect()
    }
}

/// A deterministic, time-sorted scenario trace.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioTrace {
    label: String,
    events: Vec<TimedEvent>,
}

impl ScenarioTrace {
    /// The empty trace: dynamics plumbing active, zero events — the
    /// engine must reproduce static metrics bit-for-bit.
    pub fn empty(label: impl Into<String>) -> ScenarioTrace {
        ScenarioTrace { label: label.into(), events: Vec::new() }
    }

    /// Build from explicit events. Validates times and factors, then
    /// stable-sorts by time so equal-time events keep insertion order.
    pub fn from_events(label: impl Into<String>, mut events: Vec<TimedEvent>) -> ScenarioTrace {
        for te in &events {
            assert!(
                te.time.is_finite() && te.time >= 0.0,
                "event time must be finite and non-negative, got {}",
                te.time
            );
            let factor = match te.event {
                DynEvent::WanScale { factor }
                | DynEvent::ClusterLinkScale { factor, .. }
                | DynEvent::MapperSlowdown { factor, .. }
                | DynEvent::ReducerSlowdown { factor, .. } => Some(factor),
                DynEvent::MapperFail { .. }
                | DynEvent::MapperRecover { .. }
                | DynEvent::ReducerFail { .. }
                | DynEvent::ReducerRecover { .. } => None,
                DynEvent::SourceRefresh { fraction, .. } => {
                    assert!(
                        fraction.is_finite() && fraction > 0.0 && fraction <= 1.0,
                        "refresh fraction must be in (0, 1], got {fraction}"
                    );
                    None
                }
            };
            if let Some(f) = factor {
                assert!(
                    f.is_finite() && f >= MIN_FACTOR,
                    "scale factor must be finite and ≥ {MIN_FACTOR}, got {f}"
                );
            }
        }
        events.sort_by(|a, b| a.time.total_cmp(&b.time));
        ScenarioTrace { label: label.into(), events }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Events in non-decreasing time order.
    pub fn events(&self) -> &[TimedEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate the `profile` trace for `shape`, deterministically from
    /// `seed`.
    pub fn generate(profile: DynProfile, seed: u64, shape: &TraceShape) -> ScenarioTrace {
        assert!(
            shape.horizon.is_finite() && shape.horizon > 0.0,
            "trace horizon must be positive, got {}",
            shape.horizon
        );
        let mut rng = Pcg64::new(seed);
        let events = match profile {
            DynProfile::Step => gen_step(&mut rng, shape),
            DynProfile::Periodic => gen_periodic(&mut rng, shape),
            DynProfile::Burst => gen_burst(&mut rng, shape),
            DynProfile::Failures => gen_failures(&mut rng, shape),
            DynProfile::Stragglers => gen_stragglers(&mut rng, shape),
            DynProfile::Staleness => gen_staleness(&mut rng, shape),
            DynProfile::Churn => {
                let mut all = gen_burst(&mut rng.fork(), shape);
                all.extend(gen_failures(&mut rng.fork(), shape));
                all.extend(gen_stragglers(&mut rng.fork(), shape));
                all
            }
        };
        ScenarioTrace::from_events(format!("{}:{seed}", profile.label()), events)
    }
}

fn gen_step(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let drop_at = h * rng.uniform(0.15, 0.30);
    let factor = rng.uniform(0.25, 0.45);
    let recover_at = h * rng.uniform(0.55, 0.75);
    vec![
        TimedEvent { time: drop_at, event: DynEvent::WanScale { factor } },
        TimedEvent { time: recover_at, event: DynEvent::WanScale { factor: 1.0 } },
    ]
}

fn gen_periodic(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let period = h * rng.uniform(0.12, 0.20);
    let low = rng.uniform(0.35, 0.60);
    let mut events = Vec::new();
    // Cover well past the horizon (the job usually outlives its estimate
    // under degradation); cap the count so traces stay small.
    let mut t = period;
    let mut degraded = true;
    while t < 2.0 * h && events.len() < 32 {
        let factor = if degraded { low } else { 1.0 };
        events.push(TimedEvent { time: t, event: DynEvent::WanScale { factor } });
        degraded = !degraded;
        t += period;
    }
    // Always end restored so a long tail runs at full speed.
    events.push(TimedEvent { time: t, event: DynEvent::WanScale { factor: 1.0 } });
    events
}

fn gen_burst(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let n_bursts = 4 + (shape.n_clusters / 4).min(4);
    let zipf = Zipf::new(shape.n_clusters as u64, 1.2);
    let mut events = Vec::new();
    for _ in 0..n_bursts {
        let cluster = (zipf.sample(rng) - 1) as usize;
        let t0 = h * rng.uniform(0.05, 0.60);
        let dur = h * rng.uniform(0.10, 0.25);
        let factor = rng.uniform(0.05, 0.20).max(MIN_FACTOR);
        events.push(TimedEvent { time: t0, event: DynEvent::ClusterLinkScale { cluster, factor } });
        events.push(TimedEvent {
            time: t0 + dur,
            event: DynEvent::ClusterLinkScale { cluster, factor: 1.0 },
        });
        // Correlated outage: the WAN incident usually takes a machine in
        // the bursted cluster with it, recovering after the links do.
        let members = shape.mappers_in(cluster);
        if !members.is_empty() && rng.chance(0.75) {
            let node = members[rng.range(0, members.len())];
            let back = t0 + dur * rng.uniform(1.2, 2.0);
            events.push(TimedEvent { time: t0, event: DynEvent::MapperFail { node } });
            events.push(TimedEvent { time: back, event: DynEvent::MapperRecover { node } });
        }
    }
    events
}

fn gen_failures(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let m = shape.n_mappers();
    let n_fail = (m / 6).max(1);
    // Distinct victims: shuffle the node ids, take the first n_fail.
    let mut nodes: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut nodes);
    nodes.truncate(n_fail);
    nodes.sort_unstable();
    let mut events = Vec::new();
    for node in nodes {
        let fail_at = h * rng.uniform(0.05, 0.15);
        let recover_at = h * rng.uniform(0.55, 0.85);
        events.push(TimedEvent { time: fail_at, event: DynEvent::MapperFail { node } });
        events.push(TimedEvent { time: recover_at, event: DynEvent::MapperRecover { node } });
    }
    // Reducer outages (drawn *after* the mapper events so the mapper part
    // of the stream is unchanged for a given seed). Victims come from the
    // top of the attractiveness ranking — where plans concentrate the
    // shuffle — failing mid-run (the shuffle is in flight under Hadoop's
    // pipelined map/shuffle boundary) and recovering only around the
    // nominal end of the job, so an un-hedged plan that waits for
    // recovery pays for the whole outage.
    if shape.n_reducers > 0 {
        let n_red = (shape.n_reducers / 8).max(1).min(shape.reducer_rank.len());
        for &node in shape.reducer_rank.iter().take(n_red) {
            let fail_at = h * rng.uniform(0.30, 0.50);
            let recover_at = h * rng.uniform(0.90, 1.15);
            events.push(TimedEvent { time: fail_at, event: DynEvent::ReducerFail { node } });
            events
                .push(TimedEvent { time: recover_at, event: DynEvent::ReducerRecover { node } });
        }
    }
    events
}

fn gen_stragglers(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let m = shape.n_mappers();
    let n_slow = (m / 5).max(1);
    let mut nodes: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut nodes);
    nodes.truncate(n_slow);
    nodes.sort_unstable();
    let mut events = Vec::new();
    for node in nodes {
        let t0 = h * rng.uniform(0.05, 0.40);
        let dur = h * rng.uniform(0.30, 0.50);
        let factor = rng.uniform(0.08, 0.25).max(MIN_FACTOR);
        events.push(TimedEvent { time: t0, event: DynEvent::MapperSlowdown { node, factor } });
        events.push(TimedEvent {
            time: t0 + dur,
            event: DynEvent::MapperSlowdown { node, factor: 1.0 },
        });
    }
    if shape.n_reducers > 0 {
        let node = rng.range(0, shape.n_reducers);
        let factor = rng.uniform(0.20, 0.50).max(MIN_FACTOR);
        let t0 = h * rng.uniform(0.40, 0.60);
        events.push(TimedEvent { time: t0, event: DynEvent::ReducerSlowdown { node, factor } });
        events.push(TimedEvent {
            time: t0 + h * 0.30,
            event: DynEvent::ReducerSlowdown { node, factor: 1.0 },
        });
    }
    events
}

/// Correlated data staleness: Zipf-popular sources refresh fractions of
/// their data while the push is (likely) still in progress. Times are
/// drawn early in the horizon so a push-bound job reliably sees at least
/// one refresh land before its splits seal; refreshes landing after the
/// push are harmless no-ops.
fn gen_staleness(rng: &mut Pcg64, shape: &TraceShape) -> Vec<TimedEvent> {
    let h = shape.horizon;
    let s = shape.n_sources;
    if s == 0 {
        return Vec::new();
    }
    let n_refresh = (s / 3).max(3);
    let zipf = Zipf::new(s as u64, 1.1);
    let mut events = Vec::new();
    for _ in 0..n_refresh {
        let source = (zipf.sample(rng) - 1) as usize;
        let t = h * rng.uniform(0.02, 0.25);
        let fraction = rng.uniform(0.20, 0.60);
        events.push(TimedEvent { time: t, event: DynEvent::SourceRefresh { source, fraction } });
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> TraceShape {
        TraceShape {
            horizon: 100.0,
            n_clusters: 4,
            mapper_cluster: (0..12).map(|j| j % 4).collect(),
            n_sources: 6,
            n_reducers: 12,
            reducer_rank: (0..12).rev().collect(),
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        for p in DynProfile::all() {
            let a = ScenarioTrace::generate(p, 9, &shape());
            let b = ScenarioTrace::generate(p, 9, &shape());
            let c = ScenarioTrace::generate(p, 10, &shape());
            assert_eq!(a, b, "{p:?} not deterministic");
            assert_ne!(a.events(), c.events(), "{p:?} seed has no effect");
        }
    }

    #[test]
    fn events_are_time_sorted_and_in_bounds() {
        for p in DynProfile::all() {
            for seed in [1u64, 7, 42] {
                let tr = ScenarioTrace::generate(p, seed, &shape());
                assert!(!tr.is_empty(), "{p:?} generated nothing");
                let mut last = 0.0;
                for te in tr.events() {
                    assert!(te.time >= last, "{p:?}: unsorted at {}", te.time);
                    last = te.time;
                    match te.event {
                        DynEvent::ClusterLinkScale { cluster, .. } => {
                            assert!(cluster < shape().n_clusters)
                        }
                        DynEvent::MapperFail { node }
                        | DynEvent::MapperRecover { node }
                        | DynEvent::MapperSlowdown { node, .. } => {
                            assert!(node < shape().mapper_cluster.len())
                        }
                        DynEvent::ReducerSlowdown { node, .. }
                        | DynEvent::ReducerFail { node }
                        | DynEvent::ReducerRecover { node } => {
                            assert!(node < shape().n_reducers)
                        }
                        DynEvent::SourceRefresh { source, fraction } => {
                            assert!(source < shape().n_sources);
                            assert!(fraction > 0.0 && fraction <= 1.0);
                        }
                        DynEvent::WanScale { .. } => {}
                    }
                }
            }
        }
    }

    #[test]
    fn every_failure_has_a_later_recovery() {
        // Mapper and reducer outages are paired independently (a node id
        // names a different machine per role).
        for p in [DynProfile::Failures, DynProfile::Burst, DynProfile::Churn] {
            for seed in 0..20u64 {
                let tr = ScenarioTrace::generate(p, seed, &shape());
                let mut down: std::collections::BTreeMap<(bool, usize), f64> = Default::default();
                let mut recovered: std::collections::BTreeSet<(bool, usize)> = Default::default();
                for te in tr.events() {
                    let (key, is_recover) = match te.event {
                        DynEvent::MapperFail { node } => ((false, node), false),
                        DynEvent::MapperRecover { node } => ((false, node), true),
                        DynEvent::ReducerFail { node } => ((true, node), false),
                        DynEvent::ReducerRecover { node } => ((true, node), true),
                        _ => continue,
                    };
                    if is_recover {
                        let failed_at = down
                            .get(&key)
                            .unwrap_or_else(|| panic!("{p:?}: recovery without failure"));
                        assert!(te.time >= *failed_at, "{p:?}: recovery before failure");
                        recovered.insert(key);
                    } else {
                        down.entry(key).or_insert(te.time);
                    }
                }
                for key in down.keys() {
                    assert!(recovered.contains(key), "{p:?} seed {seed}: {key:?} never recovers");
                }
            }
        }
    }

    /// The failures (and hence churn) profile must take down reducers —
    /// specifically the top of the attractiveness ranking — in addition
    /// to mappers, and a reducer outage must start no earlier than 30%
    /// into the horizon (so it reliably intersects the shuffle).
    #[test]
    fn failures_profile_targets_ranked_reducers() {
        for p in [DynProfile::Failures, DynProfile::Churn] {
            for seed in [1u64, 7, 42] {
                let sh = shape();
                let tr = ScenarioTrace::generate(p, seed, &sh);
                let expected = (sh.n_reducers / 8).max(1);
                let mut seen = Vec::new();
                for te in tr.events() {
                    if let DynEvent::ReducerFail { node } = te.event {
                        assert!(te.time >= 0.30 * sh.horizon, "{p:?}: reducer fails too early");
                        seen.push(node);
                    }
                }
                assert!(
                    seen.len() >= expected.max(1),
                    "{p:?} seed {seed}: only {} reducer outages",
                    seen.len()
                );
                // Victims come from the front of the ranking.
                for node in &seen {
                    assert!(
                        sh.reducer_rank[..seen.len().max(1)].contains(node),
                        "{p:?}: victim {node} not among the top-ranked reducers"
                    );
                }
            }
        }
    }

    #[test]
    fn factors_respect_min_factor() {
        for p in DynProfile::all() {
            for seed in 0..10u64 {
                let tr = ScenarioTrace::generate(p, seed, &shape());
                for te in tr.events() {
                    if let DynEvent::WanScale { factor }
                    | DynEvent::ClusterLinkScale { factor, .. }
                    | DynEvent::MapperSlowdown { factor, .. }
                    | DynEvent::ReducerSlowdown { factor, .. } = te.event
                    {
                        assert!((MIN_FACTOR..=1.0 + 1e-12).contains(&factor));
                    }
                }
            }
        }
    }

    #[test]
    fn parse_spec_forms() {
        assert_eq!(parse_spec("burst").unwrap(), (DynProfile::Burst, DEFAULT_TRACE_SEED));
        assert_eq!(parse_spec("burst:7").unwrap(), (DynProfile::Burst, 7));
        assert_eq!(parse_spec("failures:123").unwrap(), (DynProfile::Failures, 123));
        assert!(parse_spec("nope:1").is_err());
        assert!(parse_spec("burst:x").is_err());
        assert!(parse_spec("burst:1:2").is_err());
    }

    #[test]
    fn from_events_sorts_stably() {
        let tr = ScenarioTrace::from_events(
            "manual",
            vec![
                TimedEvent { time: 5.0, event: DynEvent::WanScale { factor: 0.5 } },
                TimedEvent { time: 1.0, event: DynEvent::MapperFail { node: 0 } },
                TimedEvent { time: 5.0, event: DynEvent::WanScale { factor: 1.0 } },
            ],
        );
        assert_eq!(tr.events()[0].time, 1.0);
        // Equal-time events keep insertion order: 0.5 before 1.0.
        assert_eq!(tr.events()[1].event, DynEvent::WanScale { factor: 0.5 });
        assert_eq!(tr.events()[2].event, DynEvent::WanScale { factor: 1.0 });
    }

    #[test]
    #[should_panic(expected = "scale factor")]
    fn from_events_rejects_tiny_factors() {
        let _ = ScenarioTrace::from_events(
            "bad",
            vec![TimedEvent { time: 1.0, event: DynEvent::WanScale { factor: 0.0 } }],
        );
    }

    #[test]
    fn empty_trace_has_no_events() {
        let tr = ScenarioTrace::empty("none");
        assert!(tr.is_empty());
        assert_eq!(tr.len(), 0);
    }

    /// The staleness profile emits only early source refreshes (they must
    /// be able to intersect the push phase) with in-range fractions, and
    /// is seed-deterministic like every other profile.
    #[test]
    fn staleness_profile_refreshes_sources_early() {
        for seed in [1u64, 7, 42] {
            let sh = shape();
            let tr = ScenarioTrace::generate(DynProfile::Staleness, seed, &sh);
            assert!(tr.len() >= (sh.n_sources / 3).max(3), "too few refreshes");
            for te in tr.events() {
                match te.event {
                    DynEvent::SourceRefresh { source, fraction } => {
                        assert!(source < sh.n_sources);
                        assert!((0.20..=0.60).contains(&fraction), "fraction {fraction}");
                        assert!(
                            te.time <= 0.25 * sh.horizon,
                            "refresh at {} too late to hit the push",
                            te.time
                        );
                    }
                    other => panic!("staleness emitted a non-refresh event {other:?}"),
                }
            }
        }
    }

    #[test]
    fn staleness_handles_zero_sources() {
        let sh = TraceShape { n_sources: 0, ..shape() };
        let tr = ScenarioTrace::generate(DynProfile::Staleness, 7, &sh);
        assert!(tr.is_empty());
    }

    #[test]
    fn parse_spec_accepts_staleness() {
        assert_eq!(parse_spec("staleness").unwrap(), (DynProfile::Staleness, DEFAULT_TRACE_SEED));
        assert_eq!(parse_spec("staleness:9").unwrap(), (DynProfile::Staleness, 9));
    }

    #[test]
    #[should_panic(expected = "refresh fraction")]
    fn from_events_rejects_bad_refresh_fraction() {
        let _ = ScenarioTrace::from_events(
            "bad",
            vec![TimedEvent {
                time: 1.0,
                event: DynEvent::SourceRefresh { source: 0, fraction: 0.0 },
            }],
        );
    }
}
