//! Multi-tenant job-stream layer: a seeded arrival process feeds a job
//! queue; a cross-job [`StreamPolicy`] admits jobs; every admitted job
//! runs as its own [`super::executor`] state machine over ONE shared
//! [`FluidSim`], so concurrent jobs contend for the same WAN links,
//! NICs and CPUs under max-min fairness — the "heavy traffic from
//! millions of users" regime the single-job paper model cannot see.
//!
//! Activities are stamped with their job's index ([`FluidSim::tag`]);
//! the stream engine routes each fluid completion back to the owning
//! executor, drains per-job event heaps in admission order, and runs
//! the policy again whenever the queue or the running set changes.
//!
//! ## Invariants
//!
//! * **Single-job streams are bit-identical to [`run_job`]**: one job
//!   arriving at t = 0 replays exactly the single-job resource creation
//!   order, activity ids and event ordering, so every metric matches
//!   bit for bit per seed (tests/tenancy.rs).
//! * **Per-job exact byte conservation**: each executor keeps its own
//!   transfer tables and credit counters, so
//!   `push_bytes_delivered == push_bytes` and
//!   `shuffle_bytes_delivered == shuffle_bytes` hold for every
//!   concurrent job — including under fault injection, where replay
//!   and re-push traffic are accounted separately.
//! * **Per-job times are absolute virtual times** (shared clock):
//!   a job's latency is `finished - arrival`, not its makespan field.
//!
//! A platform [`ScenarioTrace`] passed to [`run_stream`] is shared:
//! each active executor applies due events against its own cursor, and
//! because scale factors are absolute w.r.t. the topology base, a
//! late-admitted job re-applying an old event is idempotent.
//!
//! ```no_run
//! // (no_run: doctest binaries don't carry the libxla_extension rpath)
//! use mrperf::engine::tenancy::{run_stream, ArrivalSpec, StreamJob};
//! use mrperf::engine::scheduler::stream_policy;
//! use mrperf::engine::{JobConfig, Record};
//! use mrperf::model::plan::Plan;
//! use mrperf::platform::topology::example_1_3;
//! use mrperf::platform::MB;
//!
//! let topo = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
//! let plan = Plan::local_push(&topo);
//! let config = JobConfig::default();
//! let app = mrperf::apps::SyntheticApp::new(1.0);
//! let inputs: Vec<Vec<Record>> = (0..topo.n_sources())
//!     .map(|i| vec![Record::new(format!("k{i}"), "v")])
//!     .collect();
//! let arrivals = ArrivalSpec::parse("poisson:0.05:7").unwrap().generate(3);
//! let jobs: Vec<StreamJob> = arrivals
//!     .iter()
//!     .map(|&t| StreamJob::new(t, &plan, &app, &config, &inputs))
//!     .collect();
//! let mut policy = stream_policy("fair-share").unwrap();
//! let result = run_stream(&topo, &jobs, policy.as_mut(), None).unwrap();
//! assert_eq!(result.jobs.len(), 3);
//! ```

use super::dynamics::ScenarioTrace;
use super::executor::{Executor, ResourceSet};
use super::fluid::FluidSim;
use super::job::{JobConfig, MapReduceApp, Record};
use super::metrics::JobMetrics;
use super::scheduler::{QueuedJob, StreamDecision, StreamPolicy, StreamView};
use super::snapshot::{self, RecoveryOpts};
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

#[allow(unused_imports)] // doc links
use super::executor::run_job;

/// One job submission in a stream. All jobs run on the same topology;
/// plan/app/config/inputs may differ per job.
pub struct StreamJob<'a> {
    /// Submission virtual time (≥ 0, finite).
    pub arrival: f64,
    pub plan: &'a Plan,
    pub app: &'a dyn MapReduceApp,
    pub config: &'a JobConfig,
    pub inputs: &'a [Vec<Record>],
    /// Fair-share weight: scales the job's map/reduce slot capacities
    /// at admission (1.0 = the config's counts exactly).
    pub weight: f64,
    /// Completion deadline in absolute virtual time
    /// (`f64::INFINITY` = none). Used by deadline-aware admission and
    /// by goodput accounting for every policy.
    pub deadline: f64,
    /// Estimated standalone service time (e.g. a calibration
    /// [`run_job`]); the deadline policy's slowdown estimate scales it.
    pub est_service: f64,
}

impl<'a> StreamJob<'a> {
    /// A weight-1, deadline-free submission.
    pub fn new(
        arrival: f64,
        plan: &'a Plan,
        app: &'a dyn MapReduceApp,
        config: &'a JobConfig,
        inputs: &'a [Vec<Record>],
    ) -> StreamJob<'a> {
        StreamJob {
            arrival,
            plan,
            app,
            config,
            inputs,
            weight: 1.0,
            deadline: f64::INFINITY,
            est_service: 0.0,
        }
    }
}

/// What happened to one submitted job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Submission time (copied from the [`StreamJob`]).
    pub arrival: f64,
    /// Admission time (absolute virtual time; NaN if never admitted).
    pub started: f64,
    /// Completion time (absolute virtual time; NaN if never finished).
    pub finished: f64,
    /// Dropped by admission control (or stranded un-admitted at stream
    /// end) without running.
    pub rejected: bool,
    /// Completed at or before its deadline (an infinite deadline is
    /// always met by a completed job; a rejected job never meets it).
    pub met_deadline: bool,
    /// Per-job engine metrics (`None` for rejected jobs). Phase spans
    /// are absolute virtual times on the shared clock.
    pub metrics: Option<JobMetrics>,
}

impl JobOutcome {
    /// Sojourn time: completion minus submission (NaN if rejected).
    pub fn latency(&self) -> f64 {
        self.finished - self.arrival
    }
}

/// Result of one stream run.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// One outcome per submitted job, in submission (input) order.
    pub jobs: Vec<JobOutcome>,
    /// Virtual time when the last admitted job finished.
    pub makespan: f64,
}

/// A deterministic arrival process for `mrperf experiment tenancy`'s
/// `--arrivals PROFILE[:RATE[:SEED]]` flag.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Exponential inter-arrivals at `rate` jobs per (virtual) second,
    /// drawn from a seeded [`Pcg64`] by inverse transform.
    Poisson { rate: f64, seed: u64 },
    /// Evenly spaced arrivals: job n at `n / rate`.
    Periodic { rate: f64 },
    /// Explicit arrival times (non-decreasing not required; the stream
    /// engine orders by arrival).
    Trace(Vec<f64>),
}

impl ArrivalSpec {
    /// Parse `poisson:RATE[:SEED]` | `periodic:RATE` | `trace:t1,t2,..`.
    /// Rejects zero/negative/non-finite rates and empty traces with
    /// CLI-grade messages.
    pub fn parse(spec: &str) -> Result<ArrivalSpec, String> {
        let bad = |why: &str| {
            Err(format!(
                "invalid value '{spec}' for --arrivals ({why}; expected \
                 poisson:RATE[:SEED] | periodic:RATE | trace:t1,t2,...)"
            ))
        };
        let mut parts = spec.splitn(2, ':');
        let profile = parts.next().unwrap_or("");
        let rest = parts.next();
        match profile {
            "poisson" => {
                let Some(rest) = rest else { return bad("missing rate") };
                let mut it = rest.splitn(2, ':');
                let rate_s = it.next().unwrap_or("");
                let rate: f64 = match rate_s.parse() {
                    Ok(v) => v,
                    Err(_) => return bad("rate is not a number"),
                };
                if !(rate.is_finite() && rate > 0.0) {
                    return bad("rate must be finite and > 0");
                }
                let seed = match it.next() {
                    None => 7,
                    Some(s) => match s.parse() {
                        Ok(v) => v,
                        Err(_) => return bad("seed is not an integer"),
                    },
                };
                Ok(ArrivalSpec::Poisson { rate, seed })
            }
            "periodic" => {
                let Some(rest) = rest else { return bad("missing rate") };
                let rate: f64 = match rest.parse() {
                    Ok(v) => v,
                    Err(_) => return bad("rate is not a number"),
                };
                if !(rate.is_finite() && rate > 0.0) {
                    return bad("rate must be finite and > 0");
                }
                Ok(ArrivalSpec::Periodic { rate })
            }
            "trace" => {
                let Some(rest) = rest else { return bad("missing times") };
                let mut times = Vec::new();
                for tok in rest.split(',') {
                    let t: f64 = match tok.trim().parse() {
                        Ok(v) => v,
                        Err(_) => return bad("trace time is not a number"),
                    };
                    if !(t.is_finite() && t >= 0.0) {
                        return bad("trace times must be finite and >= 0");
                    }
                    times.push(t);
                }
                if times.is_empty() {
                    return bad("empty trace");
                }
                Ok(ArrivalSpec::Trace(times))
            }
            _ => bad("unknown profile"),
        }
    }

    /// First `n` arrival times of the process, deterministically.
    pub fn generate(&self, n: usize) -> Vec<f64> {
        match self {
            ArrivalSpec::Poisson { rate, seed } => {
                let mut rng = Pcg64::new(*seed);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        let u = rng.next_f64();
                        t += -(1.0 - u).ln() / rate;
                        t
                    })
                    .collect()
            }
            ArrivalSpec::Periodic { rate } => (0..n).map(|i| i as f64 / rate).collect(),
            ArrivalSpec::Trace(times) => times.iter().take(n).copied().collect(),
        }
    }
}

fn validate<'a>(jobs: &[StreamJob<'a>], topo: &Topology) -> Result<(), String> {
    if jobs.is_empty() {
        return Err("empty job stream (need at least one job)".into());
    }
    for (i, j) in jobs.iter().enumerate() {
        if !(j.arrival.is_finite() && j.arrival >= 0.0) {
            return Err(format!(
                "job {i}: arrival time {} must be finite and >= 0",
                j.arrival
            ));
        }
        if !(j.weight.is_finite() && j.weight > 0.0) {
            return Err(format!("job {i}: weight {} must be finite and > 0", j.weight));
        }
        if j.config.dynamics.is_some() {
            return Err(format!(
                "job {i}: per-job dynamics traces are not supported in a stream; \
                 pass the trace to run_stream (it applies platform-wide)"
            ));
        }
        if j.inputs.len() != topo.n_sources() {
            return Err(format!(
                "job {i}: {} input vectors for a {}-source topology",
                j.inputs.len(),
                topo.n_sources()
            ));
        }
    }
    Ok(())
}

/// Run a stream of jobs over one shared fluid network under a cross-job
/// policy. `dynamics`, if given, is a platform-wide scenario trace
/// every active job observes. Outputs are dropped (only metrics are
/// kept) to bound memory across many jobs.
pub fn run_stream<'a>(
    topo: &'a Topology,
    jobs: &[StreamJob<'a>],
    policy: &mut dyn StreamPolicy,
    dynamics: Option<&'a ScenarioTrace>,
) -> Result<StreamResult, String> {
    // Delegates with recovery off — the same code path with the hooks
    // disabled, so the no-checkpoint behavior is identical by
    // construction.
    run_stream_with_recovery(topo, jobs, policy, dynamics, &RecoveryOpts::default())
}

/// The compatibility shape of a stream run (per-active-job shape is
/// checked by each executor's own restore).
fn stream_compat(topo: &Topology, n_jobs: usize) -> Vec<(String, Json)> {
    vec![
        ("sources".into(), Json::uint(topo.n_sources())),
        ("mappers".into(), Json::uint(topo.n_mappers())),
        ("reducers".into(), Json::uint(topo.n_reducers())),
        ("jobs".into(), Json::uint(n_jobs)),
    ]
}

fn encode_outcome(o: &JobOutcome) -> Json {
    Json::Obj(vec![
        ("arrival".into(), Json::f64_bits(o.arrival)),
        ("started".into(), Json::f64_bits(o.started)),
        ("finished".into(), Json::f64_bits(o.finished)),
        ("rejected".into(), Json::Bool(o.rejected)),
        ("met_deadline".into(), Json::Bool(o.met_deadline)),
        (
            "metrics".into(),
            match &o.metrics {
                Some(m) => snapshot::encode_metrics(m),
                None => Json::Null,
            },
        ),
    ])
}

fn decode_outcome(j: &Json) -> Result<JobOutcome, String> {
    let metrics = match j.field("metrics")? {
        Json::Null => None,
        m => Some(snapshot::decode_metrics(m)?),
    };
    Ok(JobOutcome {
        arrival: j.field("arrival")?.as_f64_bits()?,
        started: j.field("started")?.as_f64_bits()?,
        finished: j.field("finished")?.as_f64_bits()?,
        rejected: j.field("rejected")?.as_bool()?,
        met_deadline: j.field("met_deadline")?.as_bool()?,
        metrics,
    })
}

/// Serialize a stream run at an event boundary (every active job's
/// event heap drained; in-flight work lives in the fluid state).
#[allow(clippy::too_many_arguments)]
fn snapshot_stream(
    sim: &FluidSim,
    topo: &Topology,
    n_jobs: usize,
    next_arrival: usize,
    queued: &[QueuedJob],
    active: &[(usize, Executor<'_>)],
    outcomes: &[JobOutcome],
    makespan: f64,
) -> Json {
    Json::Obj(vec![
        ("format".into(), Json::Str(snapshot::SNAPSHOT_FORMAT.into())),
        ("version".into(), Json::u64(snapshot::SNAPSHOT_VERSION)),
        ("kind".into(), Json::Str("stream".into())),
        ("compat".into(), Json::Obj(stream_compat(topo, n_jobs))),
        ("fluid".into(), snapshot::encode_fluid(&sim.export_state())),
        (
            "stream".into(),
            Json::Obj(vec![
                ("next_arrival".into(), Json::uint(next_arrival)),
                ("makespan".into(), Json::f64_bits(makespan)),
                (
                    "queued".into(),
                    Json::Arr(queued.iter().map(|q| Json::uint(q.job)).collect()),
                ),
                (
                    "outcomes".into(),
                    Json::Arr(outcomes.iter().map(encode_outcome).collect()),
                ),
                (
                    "active".into(),
                    Json::Arr(
                        active
                            .iter()
                            .map(|(job, exec)| {
                                Json::Obj(vec![
                                    ("job".into(), Json::uint(*job)),
                                    ("exec".into(), exec.encode_state()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ])
}

/// [`run_stream`] with checkpoint/crash/resume support — the stream
/// counterpart of [`snapshot::run_job_with_recovery`]. The coordinator
/// snapshots the shared fluid network, the arrival cursor, the queue,
/// every outcome and every active executor; a simulated crash drops all
/// of it and resumes from the latest checkpoint. Stream policies are
/// stateless (decisions are pure functions of the [`StreamView`]), so
/// the policy instance survives the restart unchanged. On completion,
/// every finished job's metrics carry `coordinator_restarts`; all other
/// fields are bit-identical to the uninterrupted run.
pub fn run_stream_with_recovery<'a>(
    topo: &'a Topology,
    jobs: &[StreamJob<'a>],
    policy: &mut dyn StreamPolicy,
    dynamics: Option<&'a ScenarioTrace>,
    opts: &RecoveryOpts,
) -> Result<StreamResult, String> {
    validate(jobs, topo)?;
    opts.validate()?;

    // Submission order: (arrival, input index) — deterministic.
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| {
        jobs[a].arrival.total_cmp(&jobs[b].arrival).then(a.cmp(&b))
    });

    let mut snapshot_text: Option<String> = opts.resume_from.clone();
    let mut crash_pending = opts.crash_at;
    let mut restarts = 0usize;

    'coordinator: loop {
    let mut sim;
    let mut next_arrival; // cursor into `order`
    let mut queued: Vec<QueuedJob>;
    // Admission order; each executor's activities carry its job index
    // as the fluid tag.
    let mut active: Vec<(usize, Executor<'a>)>;
    let mut outcomes: Vec<JobOutcome>;
    let mut makespan;
    // Resource-id layout for admissions: identical whether the sim was
    // freshly built (`build` asserts against it) or restored.
    let res = ResourceSet::layout(topo);

    match &snapshot_text {
        Some(text) => {
            let doc = Json::parse(text).map_err(|e| format!("malformed snapshot: {e}"))?;
            snapshot::check_header(&doc, "stream")?;
            snapshot::check_compat(&stream_compat(topo, jobs.len()), doc.field("compat")?)?;
            let fluid = snapshot::decode_fluid(doc.field("fluid")?)?;
            let n_activities = fluid.activities.len();
            sim = FluidSim::from_state(&fluid)?;
            let st = doc.field("stream")?;
            next_arrival = st.field("next_arrival")?.as_usize()?;
            if next_arrival > order.len() {
                return Err("snapshot arrival cursor past the end of the stream".into());
            }
            makespan = st.field("makespan")?.as_f64_bits()?;
            queued = Vec::new();
            for q in st.field("queued")?.as_arr()? {
                let job = q.as_usize()?;
                if job >= jobs.len() {
                    return Err(format!("snapshot queues unknown job {job}"));
                }
                queued.push(QueuedJob {
                    job,
                    arrival: jobs[job].arrival,
                    weight: jobs[job].weight,
                    deadline: jobs[job].deadline,
                    est_service: jobs[job].est_service,
                });
            }
            let outs = st.field("outcomes")?.as_arr()?;
            if outs.len() != jobs.len() {
                return Err(format!(
                    "snapshot has {} outcomes for a {}-job stream",
                    outs.len(),
                    jobs.len()
                ));
            }
            outcomes = outs.iter().map(decode_outcome).collect::<Result<_, _>>()?;
            active = Vec::new();
            for a in st.field("active")?.as_arr()? {
                let job = a.field("job")?.as_usize()?;
                if job >= jobs.len() {
                    return Err(format!("snapshot activates unknown job {job}"));
                }
                let sj = &jobs[job];
                let mut exec = Executor::new(
                    topo,
                    sj.plan,
                    sj.app,
                    sj.config,
                    sj.inputs,
                    res.clone(),
                    dynamics,
                    job as u64,
                    sj.weight,
                );
                exec.restore_state(a.field("exec")?, n_activities)?;
                active.push((job, exec));
            }
        }
        None => {
            sim = FluidSim::new();
            // The stream shares one simulator: solve with the widest
            // thread request among the jobs (bit-identical for every
            // value ≥ 1).
            sim.set_threads(
                jobs.iter().map(|j| j.config.threads).max().unwrap_or(1).max(1),
            );
            ResourceSet::build(&mut sim, topo);
            outcomes = jobs
                .iter()
                .map(|j| JobOutcome {
                    arrival: j.arrival,
                    started: f64::NAN,
                    finished: f64::NAN,
                    rejected: false,
                    met_deadline: false,
                    metrics: None,
                })
                .collect();
            next_arrival = 0;
            queued = Vec::new();
            active = Vec::new();
            makespan = 0.0f64;
        }
    }

    // Checkpoint cadence: the first multiple of the interval strictly
    // past the current clock.
    let mut next_ckpt = opts.checkpoint_every.map(|every| {
        let mut t = every;
        while t <= sim.now() {
            t += every;
        }
        t
    });
    let mut crashed = false;

    // Apply the policy over the current queue; returns true if any job
    // was admitted (the caller may need to re-check idle exit).
    let mut admit = |sim: &mut FluidSim,
                     queued: &mut Vec<QueuedJob>,
                     active: &mut Vec<(usize, Executor<'a>)>,
                     outcomes: &mut Vec<JobOutcome>|
     -> bool {
        if queued.is_empty() {
            return false;
        }
        let decisions = {
            let view = StreamView { now: sim.now(), queued, running: active.len() };
            policy.decide(&view)
        };
        let mut admitted_any = false;
        for d in decisions {
            // Enforce the contract: only currently queued jobs can be
            // admitted or rejected, each at most once.
            match d {
                StreamDecision::Admit(job) => {
                    let Some(pos) = queued.iter().position(|q| q.job == job) else {
                        continue;
                    };
                    queued.remove(pos);
                    let sj = &jobs[job];
                    let mut exec = Executor::new(
                        topo,
                        sj.plan,
                        sj.app,
                        sj.config,
                        sj.inputs,
                        res.clone(),
                        dynamics,
                        job as u64,
                        sj.weight,
                    );
                    outcomes[job].started = sim.now();
                    // Due trace events apply at admission (factors are
                    // absolute, so re-application is idempotent), then
                    // the push goes on the wire.
                    exec.start(sim);
                    active.push((job, exec));
                    admitted_any = true;
                }
                StreamDecision::Reject(job) => {
                    let Some(pos) = queued.iter().position(|q| q.job == job) else {
                        continue;
                    };
                    queued.remove(pos);
                    outcomes[job].rejected = true;
                }
            }
        }
        admitted_any
    };

    loop {
        // Crash/checkpoint hooks fire at event boundaries (loop top:
        // every active job's event heap is drained here). Crash is
        // checked first — a checkpoint due at the crash instant is
        // lost with the coordinator.
        if let Some(t2) = crash_pending {
            if sim.now() >= t2 {
                crash_pending = None;
                restarts += 1;
                crashed = true;
                break;
            }
        }
        if let (Some(every), Some(next)) = (opts.checkpoint_every, next_ckpt.as_mut()) {
            while sim.now() >= *next {
                let text = snapshot_stream(
                    &sim,
                    topo,
                    jobs.len(),
                    next_arrival,
                    &queued,
                    &active,
                    &outcomes,
                    makespan,
                )
                .render();
                if let Some(path) = &opts.checkpoint_path {
                    std::fs::write(path, &text)
                        .map_err(|e| format!("cannot write checkpoint `{path}`: {e}"))?;
                }
                snapshot_text = Some(text);
                *next += every;
            }
        }
        // Never step past the next arrival or the next scenario event
        // of any active job.
        let mut bound: Option<f64> = order
            .get(next_arrival)
            .map(|&j| jobs[j].arrival.max(sim.now()));
        for (_, exec) in &active {
            if let Some(t) = exec.next_dyn_time() {
                bound = Some(match bound {
                    None => t,
                    Some(b) => b.min(t),
                });
            }
        }

        let step = match bound {
            Some(tt) if sim.active_count() > 0 => sim.step_until(tt),
            Some(tt) => {
                // Nothing in flight: idle-jump to the arrival/event.
                sim.jump_to(tt);
                Some((sim.now(), Vec::new()))
            }
            None => sim.step(),
        };

        let Some((now, completed)) = step else {
            // Simulation drained with no future arrivals bound. Give
            // the policy a last chance over whatever is still queued;
            // if nothing is admitted we are done.
            if admit(&mut sim, &mut queued, &mut active, &mut outcomes) {
                continue;
            }
            break;
        };

        if completed.is_empty() {
            // Reached the bound: enqueue due arrivals, inject due
            // scenario events, then let the policy react.
            while let Some(&j) = order.get(next_arrival) {
                if jobs[j].arrival > now {
                    break;
                }
                next_arrival += 1;
                queued.push(QueuedJob {
                    job: j,
                    arrival: jobs[j].arrival,
                    weight: jobs[j].weight,
                    deadline: jobs[j].deadline,
                    est_service: jobs[j].est_service,
                });
            }
            for (_, exec) in active.iter_mut() {
                exec.apply_dynamics(&mut sim);
            }
            admit(&mut sim, &mut queued, &mut active, &mut outcomes);
            continue;
        }

        // Route each completion to its owning job's event heap, then
        // drain and straggler-check per job in admission order.
        for aid in completed {
            let tag = sim.tag(aid);
            if let Some((_, exec)) = active.iter_mut().find(|(j, _)| *j as u64 == tag) {
                exec.enqueue(now, aid);
            }
            // else: activity of a job that already completed (a
            // cancelled losing copy) — nothing to dispatch.
        }
        for (_, exec) in active.iter_mut() {
            exec.drain(&mut sim);
        }
        for (_, exec) in active.iter_mut() {
            exec.maybe_speculate(&mut sim);
        }

        // Harvest finished jobs (admission order preserved).
        let mut finished_any = false;
        let mut i = 0;
        while i < active.len() {
            if active[i].1.is_complete() {
                let (job, exec) = active.remove(i);
                let result = exec.into_result();
                let fin = result.metrics.makespan;
                outcomes[job].finished = fin;
                outcomes[job].met_deadline = fin <= jobs[job].deadline;
                outcomes[job].metrics = Some(result.metrics);
                makespan = makespan.max(fin);
                finished_any = true;
            } else {
                i += 1;
            }
        }
        if finished_any {
            admit(&mut sim, &mut queued, &mut active, &mut outcomes);
        }
    }

    if crashed {
        // Drop the in-memory coordinator; the next iteration resumes
        // from the latest snapshot — through the file when one is
        // configured — or restarts cold if none was taken yet.
        if let Some(path) = &opts.checkpoint_path {
            if snapshot_text.is_some() {
                snapshot_text = Some(
                    std::fs::read_to_string(path)
                        .map_err(|e| format!("cannot read checkpoint `{path}`: {e}"))?,
                );
            }
        }
        continue 'coordinator;
    }

    assert!(active.is_empty(), "stream ended with jobs still running");
    // Jobs still queued when the stream drains were never admitted
    // (e.g. FIFO never got an idle slot before arrivals stopped —
    // impossible — or the policy declined them): count as rejected.
    for q in queued {
        outcomes[q.job].rejected = true;
    }
    // Restart provenance (excluded from the determinism signature):
    // every job that produced metrics records the stream's survived
    // crash/restart cycles.
    for o in outcomes.iter_mut() {
        if let Some(m) = o.metrics.as_mut() {
            m.coordinator_restarts = restarts;
        }
    }
    return Ok(StreamResult { jobs: outcomes, makespan });
    } // 'coordinator
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_poisson_with_and_without_seed() {
        assert_eq!(
            ArrivalSpec::parse("poisson:0.5").unwrap(),
            ArrivalSpec::Poisson { rate: 0.5, seed: 7 }
        );
        assert_eq!(
            ArrivalSpec::parse("poisson:2:99").unwrap(),
            ArrivalSpec::Poisson { rate: 2.0, seed: 99 }
        );
        assert_eq!(
            ArrivalSpec::parse("periodic:0.25").unwrap(),
            ArrivalSpec::Periodic { rate: 0.25 }
        );
        assert_eq!(
            ArrivalSpec::parse("trace:0,5,9.5").unwrap(),
            ArrivalSpec::Trace(vec![0.0, 5.0, 9.5])
        );
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "poisson:0",
            "poisson:-1",
            "poisson:inf",
            "poisson:abc",
            "poisson",
            "periodic:0",
            "periodic:-2",
            "periodic",
            "trace:",
            "trace:1,-3",
            "trace:1,nan",
            "uniform:1",
            "",
        ] {
            let e = ArrivalSpec::parse(bad).unwrap_err();
            assert!(e.contains("--arrivals"), "{bad}: {e}");
        }
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_increasing() {
        let spec = ArrivalSpec::Poisson { rate: 0.1, seed: 42 };
        let a = spec.generate(50);
        let b = spec.generate(50);
        assert_eq!(a, b, "same seed, same arrivals");
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrivals strictly increase");
        }
        let other = ArrivalSpec::Poisson { rate: 0.1, seed: 43 }.generate(50);
        assert_ne!(a, other, "different seed, different arrivals");
        // Mean inter-arrival ≈ 1/rate over 50 draws (loose check).
        let mean = a.last().unwrap() / 50.0;
        assert!((5.0..20.0).contains(&mean), "mean inter-arrival {mean}");
    }

    #[test]
    fn periodic_arrivals_evenly_spaced() {
        let a = ArrivalSpec::Periodic { rate: 0.5 }.generate(4);
        assert_eq!(a, vec![0.0, 2.0, 4.0, 6.0]);
    }
}
