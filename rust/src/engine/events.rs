//! Virtual-clock event machinery for the engine core.
//!
//! [`EventQueue`] is a deterministic min-heap of timestamped events: pops
//! are globally ordered by `(virtual time, insertion sequence)`, so the
//! executor dispatches phase transitions in exactly the order the fluid
//! simulation completes them, and same-time events are delivered FIFO.
//! Two invariants are property-tested (tests/engine_props.rs):
//!
//! * pops occur in non-decreasing virtual time (pushes dated in the past
//!   are clamped to the clock — an event can never fire before "now");
//! * every pushed event is eventually delivered exactly once.
//!
//! [`EngineEvent`] is the executor's event vocabulary: each variant is
//! one phase transition of the MapReduce pipeline (§3.1), produced when
//! the fluid activity that models the transfer/compute completes.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Index of a map task in the executor's task table.
pub type TaskId = usize;

/// A phase-transition event on the engine's virtual timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineEvent {
    /// Push transfer `xfer` (an index into the executor's push-transfer
    /// table, which records task, source, target node and byte count —
    /// the state a source refresh needs to re-send it) was fully
    /// delivered: one part (or replica copy) of a map task's input split
    /// arrived at its mapper (§3.1.2 push).
    PushArrived { xfer: usize },
    /// A remote fetch of a task's split finished — the stolen
    /// (`speculative: false`) or backup-copy (`true`) path of §4.6.4.
    FetchArrived { task: TaskId, speculative: bool },
    /// A map task's compute finished (primary or speculative copy).
    MapFinished { task: TaskId, speculative: bool },
    /// Shuffle transfer `xfer` (an index into the executor's transfer
    /// table, which records source node, key range, payload and byte
    /// count — the state a reducer failure needs to replay it) was fully
    /// delivered (§3.1.3).
    ShuffleArrived { xfer: usize },
    /// The reduce compute of key range `range` finished (on whichever
    /// reducer currently owns the range — ownership moves on failures).
    ReduceFinished { range: usize },
    /// One replicated output write of key range `range` completed
    /// (§4.6.5).
    OutputWritten { range: usize },
}

struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed so BinaryHeap (a max-heap) pops the earliest time,
        // breaking ties by insertion order (FIFO). Times are asserted
        // finite on push, so partial_cmp cannot fail.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic timestamped event heap.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    /// Virtual time of the last pop (the queue's clock).
    last: f64,
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, last: 0.0 }
    }

    /// Schedule `event` at virtual time `time`. Times earlier than the
    /// clock (the last pop) are clamped to it: events cannot fire in the
    /// past.
    pub fn push(&mut self, time: f64, event: E) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let time = time.max(self.last);
        self.heap.push(Entry { time, seq: self.seq, event });
        self.seq += 1;
    }

    /// Deliver the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.last = e.time;
            (e.time, e.event)
        })
    }

    /// Virtual time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// The queue's clock: time of the most recent pop.
    pub fn now(&self) -> f64 {
        self.last
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Restore the clock on an **empty** queue (checkpoint resume: the
    /// executor only snapshots at event boundaries, where the heap is
    /// drained, so only the clamp floor needs to survive — a fresh
    /// insertion sequence is equivalent because relative order among
    /// co-resident entries is all `seq` ever decides). Panics if events
    /// are pending or the time is not finite.
    pub fn restore_clock(&mut self, t: f64) {
        assert!(t.is_finite(), "clock must be finite, got {t}");
        assert!(self.heap.is_empty(), "restore_clock requires an empty queue");
        self.last = t;
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    #[test]
    fn past_pushes_clamp_to_clock() {
        let mut q = EventQueue::new();
        q.push(10.0, "late");
        assert_eq!(q.pop(), Some((10.0, "late")));
        q.push(2.0, "stale");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "stale");
        assert_eq!(t, 10.0, "past event clamped to the clock");
        assert_eq!(q.now(), 10.0);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn restore_clock_sets_the_clamp_floor() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.restore_clock(7.5);
        assert_eq!(q.now(), 7.5);
        q.push(2.0, "past");
        assert_eq!(q.pop(), Some((7.5, "past")), "clamped to the restored clock");
    }

    #[test]
    #[should_panic(expected = "empty queue")]
    fn restore_clock_rejects_pending_events() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.restore_clock(2.0);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, 1);
        q.push(1.0, 2);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
