//! Integration tests for detlint: the fixture contract (shared with
//! the Python mirror via tests/fixtures/expected.txt), per-fixture
//! pass/fail polarity, the repo-clean self-test, and the `--json`
//! schema.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use detlint::{analyze_source, analyze_tree, collect_rs_files, render_json, Analysis};

fn fixtures_tree() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tree")
}

fn expected() -> Vec<(String, usize, String)> {
    let text = fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/expected.txt"),
    )
    .expect("expected.txt");
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.rsplitn(3, ':');
        let rule = parts.next().unwrap().to_string();
        let lineno: usize = parts.next().unwrap().parse().expect("line number");
        let file = parts.next().unwrap().to_string();
        out.push((file, lineno, rule));
    }
    out
}

/// The full fixture tree must produce exactly the findings pinned in
/// expected.txt — the cross-implementation contract.
#[test]
fn fixtures_match_expected() {
    let mut a = Analysis::default();
    analyze_tree(&fixtures_tree(), "", &mut a).expect("scan fixtures");
    let got: Vec<(String, usize, String)> = a
        .findings
        .iter()
        .map(|f| (f.file.clone(), f.line, f.rule.clone()))
        .collect();
    assert_eq!(got, expected(), "fixture findings drifted from expected.txt");
    // The three reasoned allow annotations in the good fixtures must
    // each suppress exactly one finding.
    assert_eq!(a.suppressed, 3, "allow-annotation suppression count");
}

/// Every `bad_*` fixture must fail on its own; every other fixture
/// must be clean on its own (single-file scans keep tree-relative
/// paths so rule scoping still applies).
#[test]
fn per_fixture_polarity() {
    let tree = fixtures_tree();
    let files = collect_rs_files(&tree).expect("list fixtures");
    assert!(files.len() >= 12, "fixture set shrank: {files:?}");
    for rel in files {
        let text = fs::read_to_string(tree.join(&rel)).expect("read fixture");
        let mut a = Analysis::default();
        analyze_source(&rel, &text, &mut a);
        let is_bad = rel.rsplit('/').next().unwrap().starts_with("bad_");
        if is_bad {
            assert!(!a.findings.is_empty(), "{rel}: expected findings, got none");
        } else {
            assert!(a.findings.is_empty(), "{rel}: expected clean, got {:?}", a.findings);
        }
    }
}

/// Every rule id appears at least once in the bad fixtures, so no rule
/// can silently stop firing.
#[test]
fn every_rule_exercised() {
    let rules: BTreeSet<String> = expected().into_iter().map(|(_, _, r)| r).collect();
    for id in detlint::RULE_IDS.iter().chain(["DLINT"].iter()) {
        assert!(rules.contains(*id), "rule {id} has no bad fixture");
    }
}

/// The repository's own source tree ships lint-clean: zero unallowed
/// findings over rust/src. This is the same gate CI runs.
#[test]
fn repo_is_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let mut a = Analysis::default();
    analyze_tree(&src, "rust/src", &mut a).expect("scan rust/src");
    assert!(a.files > 50, "suspiciously few files scanned: {}", a.files);
    assert!(
        a.findings.is_empty(),
        "rust/src has detlint findings:\n{}",
        a.findings
            .iter()
            .map(|f| format!("{}:{}: {} {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// `--json` output schema: version, counts, and per-finding keys, with
/// paths/messages escaped. (The Python mirror round-trips the same
/// output through json.loads in its --self-test.)
#[test]
fn json_schema() {
    let mut a = Analysis::default();
    analyze_tree(&fixtures_tree(), "", &mut a).expect("scan fixtures");
    let j = render_json(&a);
    assert!(j.starts_with("{\"version\":1,\"files\":"));
    assert!(j.contains("\"suppressed\":3"));
    assert!(j.contains("\"findings\":["));
    for key in ["\"file\":", "\"line\":", "\"rule\":", "\"message\":"] {
        assert!(j.contains(key), "missing {key} in JSON output");
    }
    // Structural sanity: balanced braces/brackets outside strings.
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in j.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "unbalanced JSON");
    }
    assert_eq!(depth, 0, "unbalanced JSON");
    assert!(!in_str, "unterminated string in JSON");
    assert_eq!(
        j.matches("\"rule\":").count(),
        expected().len(),
        "finding count in JSON drifted from expected.txt"
    );
}
