// Known-good D004: all randomness flows from an explicit seed.
pub fn draw(seed: u64) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}
