use std::collections::hash_map::RandomState;

pub fn draw() -> u64 {
    let mut rng = rand::thread_rng();
    let _ = RandomState::new();
    rng.gen()
}
