// Known-good D003: wall-clock timing is fine outside the deterministic
// core (util/, experiments timing, benches).
pub fn stamp() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}
