pub fn rank(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn pick(xs: &[f64]) -> f64 {
    xs.iter()
        .cloned()
        .max_by(|a, b| {
            a.partial_cmp(b).unwrap()
        })
        .unwrap()
}
