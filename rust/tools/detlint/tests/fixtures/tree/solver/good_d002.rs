use std::cmp::Ordering;

pub fn rank(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub struct Wrapped(pub f64);

impl Wrapped {
    // A partial_cmp outside a comparator-call context is not D002's
    // business (Ord impls may consult it with an explicit fallback).
    pub fn cmp_or_equal(&self, other: &Wrapped) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}
