pub fn fan_out() {
    let h = std::thread::spawn(|| {});
    h.join().unwrap();
}
