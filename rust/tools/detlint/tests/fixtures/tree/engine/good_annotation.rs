use std::collections::HashSet;

// detlint: allow-file(D001) membership counting only; no order-dependent traversal
pub fn count(s: &HashSet<u32>) -> usize {
    s.iter().count()
}
