pub struct Metrics {
    pub push_bytes_delivered: f64,
    pub push_bytes_repushed: f64,
}

pub fn credit(m: &mut Metrics, bytes: f64) {
    m.push_bytes_delivered += bytes;
    m.push_bytes_repushed += bytes;
}
