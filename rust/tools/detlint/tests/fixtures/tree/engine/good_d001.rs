// Known-good D001: sorted drains, BTreeMap, and a reasoned allow.
use std::collections::{BTreeMap, HashMap};

pub fn sorted_keys(m: &HashMap<usize, u64>) -> Vec<usize> {
    let mut ks: Vec<usize> = m.keys().copied().collect();
    ks.sort();
    ks
}

pub fn ordered(b: &BTreeMap<usize, u64>) -> u64 {
    b.values().sum()
}

pub fn tagged(m: &HashMap<usize, u64>) -> u64 {
    // detlint: allow(D001) summing is order-free (commutative integer fold)
    m.values().sum()
}
