use std::collections::HashMap;

pub fn broken(m: &HashMap<u32, u32>) -> u32 {
    // detlint: allow(D001)
    m.values().sum()
}

pub fn unknown(m: &HashMap<u32, u32>) -> u32 {
    // detlint: allow(D999) not a rule id
    m.values().sum()
}
