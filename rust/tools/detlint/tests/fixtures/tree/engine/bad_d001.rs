// Known-bad D001: unsorted hash-container iteration in engine scope.
use std::collections::HashMap;

pub fn sum_keys(m: &HashMap<usize, u64>) -> u64 {
    let mut total = 0;
    for (k, _v) in m.iter() {
        total += *k as u64;
    }
    total
}

pub fn first_key(map: HashMap<String, u32>) -> Option<String> {
    map.keys().next().cloned()
}

pub struct Holder {
    inner: HashMap<u32, u32>,
}

impl Holder {
    pub fn drain_all(&mut self) -> Vec<(u32, u32)> {
        self.inner
            .drain()
            .collect()
    }
}
