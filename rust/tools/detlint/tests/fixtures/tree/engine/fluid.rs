// Known-good D005: engine/fluid.rs is the one file allowed to spawn
// threads (the sharded fluid re-solve).
pub fn shard(n: usize) -> usize {
    let mut total = 0;
    std::thread::scope(|s| {
        let h = s.spawn(move || n);
        total += h.join().unwrap();
    });
    total
}
