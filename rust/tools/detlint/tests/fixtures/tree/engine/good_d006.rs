pub struct Metrics {
    pub shuffle_bytes_delivered: f64,
    pub reduce_bytes_replayed: f64,
}

pub fn credit(m: &mut Metrics, bytes: f64) {
    // Exact: byte counts are integers < 2^53 carried in f64.
    m.shuffle_bytes_delivered += bytes;
}

pub fn replay(m: &mut Metrics, bytes: f64) {
    // detlint: allow(D006) replay credit audited by the conservation tests
    m.reduce_bytes_replayed += bytes;
}
