pub fn stamp() -> u64 {
    let t0 = std::time::Instant::now();
    let _ = t0;
    0
}
