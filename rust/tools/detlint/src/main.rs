//! detlint CLI. `detlint [--json] [PATH ...]` — PATHs are files or
//! directories (default `rust/src`). Exit 0 when clean, 1 on any
//! unallowed finding, 2 on usage/IO errors.

use std::path::Path;
use std::process::ExitCode;

use detlint::{analyze_source, analyze_tree, render_json, Analysis};

const USAGE: &str = "usage: detlint [--json] [PATH ...]\n\
    Static determinism/invariant analysis for the mrperf tree.\n\
    PATH defaults to rust/src. Exit 0 clean, 1 findings, 2 errors.\n\
    Rules and allow-annotation syntax: docs/LINTS.md";

fn main() -> ExitCode {
    let mut json = false;
    let mut paths: Vec<String> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("detlint: unknown flag `{a}`\n{USAGE}");
                return ExitCode::from(2);
            }
            a => paths.push(a.to_string()),
        }
    }
    if paths.is_empty() {
        paths.push("rust/src".to_string());
    }

    let mut analysis = Analysis::default();
    for p in &paths {
        let path = Path::new(p);
        if path.is_dir() {
            if let Err(e) = analyze_tree(path, p, &mut analysis) {
                eprintln!("detlint: error scanning `{p}`: {e}");
                return ExitCode::from(2);
            }
        } else if path.is_file() {
            match std::fs::read_to_string(path) {
                Ok(text) => analyze_source(p, &text, &mut analysis),
                Err(e) => {
                    eprintln!("detlint: error reading `{p}`: {e}");
                    return ExitCode::from(2);
                }
            }
        } else {
            eprintln!("detlint: no such file or directory: `{p}`");
            return ExitCode::from(2);
        }
    }
    analysis.findings.sort();
    analysis.findings.dedup();

    if json {
        print!("{}", render_json(&analysis));
    } else {
        for f in &analysis.findings {
            println!("{}:{}: {} {}", f.file, f.line, f.rule, f.message);
        }
        println!(
            "detlint: {} finding(s) in {} file(s), {} suppressed by allow",
            analysis.findings.len(),
            analysis.files,
            analysis.suppressed
        );
    }
    if analysis.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
