//! detlint — determinism & invariant static analysis for the mrperf tree.
//!
//! The engine's headline guarantees (bit-identical replay per seed,
//! zero-event neutrality, thread-count-invariant metrics, exact byte
//! conservation) rest on coding rules that no compiler checks. detlint
//! machine-checks them at CI time, with no toolchain dependency beyond
//! the analyzer itself: the pass is line/token-based over a
//! comment/string-masked view of each source file — no `syn`, no
//! crates.io, mirroring the library's zero-dependency discipline.
//!
//! Rule catalog (see `docs/LINTS.md` for the invariant each protects):
//!
//! * **D001** — iteration over `HashMap`/`HashSet` (`.iter()`, `.keys()`,
//!   `.values()`, `.drain()`, `.into_iter()`, `for … in &map`) inside
//!   `engine/`, `optimizer/` or `experiments/`, unless the result is
//!   explicitly sorted nearby or the site carries an allow annotation.
//! * **D002** — `partial_cmp` inside a `sort_by` / `sort_unstable_by` /
//!   `max_by` / `min_by` / `binary_search_by` comparator (anywhere);
//!   NaN-safe ordering requires `total_cmp`.
//! * **D003** — wall-clock time (`Instant::now`, `SystemTime`,
//!   `std::time`) inside `engine/`, `model/`, `solver/`, `optimizer/`;
//!   bench files (path containing `bench`) are allowlisted.
//! * **D004** — ambient randomness (`thread_rng`, `rand::random`,
//!   `RandomState`) anywhere.
//! * **D005** — thread creation (`std::thread`, `thread::spawn`,
//!   `.spawn(`) anywhere except `engine/fluid.rs` (the sharded re-solve).
//! * **D006** — `+=` into an exact-conservation counter (a field whose
//!   name ends in `_bytes_delivered`, `_repushed` or `_replayed`)
//!   without an adjacent comment containing `exact` within the three
//!   preceding lines.
//!
//! Annotations: `// detlint: allow(D001) <reason>` suppresses a finding
//! on the same line, or — when the comment stands on its own line — on
//! the next code line. `// detlint: allow-file(D001) <reason>`
//! suppresses a rule for the whole file. A missing or empty reason is
//! itself an error (rule id `DLINT`), and malformed annotations never
//! suppress anything.
//!
//! `scripts/detlint.py` is a line-for-line behavioral mirror used by
//! toolchain-less CI containers; `tests/fixtures/` pins both
//! implementations to the same findings.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::Path;

/// Rule ids detlint can emit (besides the meta-rule `DLINT`).
pub const RULE_IDS: [&str; 6] = ["D001", "D002", "D003", "D004", "D005", "D006"];

/// How many lines after a flagged hash iteration an explicit `.sort`
/// (or `BTree` re-collection) counts as "the result flows through a
/// sort" (the collect-then-sort idiom).
pub const D001_SORT_WINDOW: usize = 8;

/// How many lines above a D006 credit an `exact` comment counts as
/// adjacent.
pub const D006_COMMENT_WINDOW: usize = 3;

/// One diagnostic. `file` is the display path exactly as reported.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
}

/// Aggregate result of an analysis run.
#[derive(Debug, Default)]
pub struct Analysis {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Unsuppressed findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings suppressed by a well-formed allow annotation.
    pub suppressed: usize,
}

/// A source file split into a comment-stream and a code-stream, line by
/// line. String/char-literal contents are blanked out of the code
/// stream (so tokens inside literals never match) and comments are
/// blanked too; the comment stream holds only comment text.
#[derive(Debug)]
pub struct Masked {
    pub code: Vec<String>,
    pub comment: Vec<String>,
}

fn is_word_b(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Mask comments and string/char literals. Handles line comments,
/// nested block comments, `"…"` (with escapes), `r"…"`/`r#"…"#` raw
/// strings, byte strings, char literals and lifetimes.
pub fn mask_source(text: &str) -> Masked {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
        Chr,
    }
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(n);
    let mut com = String::with_capacity(n);
    let mut st = St::Code;
    let mut i = 0usize;
    // Push `k` placeholder spaces to one stream and real chars to none.
    let blank = |s: &mut String, t: &mut String, k: usize| {
        for _ in 0..k {
            s.push(' ');
            t.push(' ');
        }
    };
    while i < n {
        let c = chars[i];
        if c == '\n' {
            code.push('\n');
            com.push('\n');
            if st == St::Line {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                let prev_word = i > 0 && (chars[i - 1].is_ascii_alphanumeric() || chars[i - 1] == '_');
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    code.push(' ');
                    code.push(' ');
                    com.push('/');
                    com.push('/');
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    code.push(' ');
                    code.push(' ');
                    com.push('/');
                    com.push('*');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    blank(&mut code, &mut com, 1);
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_word {
                    // r"…", r#"…"#, br"…", b"…", b'…'
                    let (mut j, is_b) = if c == 'b' { (i + 1, true) } else { (i, false) };
                    if is_b && chars.get(j).copied() == Some('\'') {
                        // byte char literal b'x'
                        blank(&mut code, &mut com, 2);
                        st = St::Chr;
                        i = j + 1;
                        continue;
                    }
                    if is_b && chars.get(j).copied() == Some('"') {
                        blank(&mut code, &mut com, 2);
                        st = St::Str;
                        i = j + 1;
                        continue;
                    }
                    if is_b && chars.get(j).copied() != Some('r') {
                        code.push(c);
                        com.push(' ');
                        i += 1;
                        continue;
                    }
                    if is_b {
                        j += 1; // past the 'r'
                    } else {
                        j = i + 1;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j).copied() == Some('#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j).copied() == Some('"') {
                        blank(&mut code, &mut com, j + 1 - i);
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        com.push(' ');
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime
                    if next == Some('\\') {
                        blank(&mut code, &mut com, 1);
                        st = St::Chr;
                        i += 1;
                    } else if chars.get(i + 2).copied() == Some('\'') && next != Some('\'') {
                        blank(&mut code, &mut com, 3);
                        i += 3;
                    } else {
                        code.push('\'');
                        com.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    com.push(' ');
                    i += 1;
                }
            }
            St::Line => {
                com.push(c);
                code.push(' ');
                i += 1;
            }
            St::Block(d) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::Block(d + 1);
                    com.push('/');
                    com.push('*');
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    com.push('*');
                    com.push('/');
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else {
                    com.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && chars.get(i + 1).map_or(false, |&x| x != '\n') {
                    blank(&mut code, &mut com, 2);
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    blank(&mut code, &mut com, 1);
                    i += 1;
                } else {
                    blank(&mut code, &mut com, 1);
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..h as usize {
                        if chars.get(i + 1 + k).copied() != Some('#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        blank(&mut code, &mut com, 1 + h as usize);
                        st = St::Code;
                        i += 1 + h as usize;
                    } else {
                        blank(&mut code, &mut com, 1);
                        i += 1;
                    }
                } else {
                    blank(&mut code, &mut com, 1);
                    i += 1;
                }
            }
            St::Chr => {
                if c == '\\' && chars.get(i + 1).map_or(false, |&x| x != '\n') {
                    blank(&mut code, &mut com, 2);
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    blank(&mut code, &mut com, 1);
                    i += 1;
                } else {
                    blank(&mut code, &mut com, 1);
                    i += 1;
                }
            }
        }
    }
    Masked {
        code: code.split('\n').map(|s| s.to_string()).collect(),
        comment: com.split('\n').map(|s| s.to_string()).collect(),
    }
}

/// Byte offsets of word-bounded occurrences of `needle` in `hay`.
/// Boundaries are only enforced on needle edges that are word chars, so
/// needles like `.spawn(` or `std::time` behave as expected.
pub fn token_positions(hay: &str, needle: &str) -> Vec<usize> {
    let hb = hay.as_bytes();
    let nb = needle.as_bytes();
    let mut out = Vec::new();
    if nb.is_empty() || hb.len() < nb.len() {
        return out;
    }
    let first_w = is_word_b(nb[0]);
    let last_w = is_word_b(nb[nb.len() - 1]);
    let mut i = 0usize;
    while i + nb.len() <= hb.len() {
        if &hb[i..i + nb.len()] == nb {
            let pre_ok = !first_w || i == 0 || !is_word_b(hb[i - 1]);
            let post_ok =
                !last_w || i + nb.len() == hb.len() || !is_word_b(hb[i + nb.len()]);
            if pre_ok && post_ok {
                out.push(i);
            }
        }
        i += 1;
    }
    out
}

/// Per-file allow state parsed from annotations.
#[derive(Debug, Default)]
struct Allows {
    file: BTreeSet<String>,
    line: BTreeMap<usize, BTreeSet<String>>,
}

/// Parse `detlint:` annotations out of the comment stream. Returns the
/// allow tables plus DLINT findings for malformed annotations.
fn parse_annotations(rel: &str, m: &Masked, findings: &mut Vec<Finding>) -> Allows {
    let mut allows = Allows::default();
    for (idx, comment) in m.comment.iter().enumerate() {
        let lineno = idx + 1;
        let pos = match comment.find("detlint:") {
            Some(p) => p,
            None => continue,
        };
        let rest = comment[pos + "detlint:".len()..].trim_start();
        let (file_scope, body) = if let Some(b) = rest.strip_prefix("allow-file(") {
            (true, b)
        } else if let Some(b) = rest.strip_prefix("allow(") {
            (false, b)
        } else {
            findings.push(Finding {
                file: rel.to_string(),
                line: lineno,
                rule: "DLINT".to_string(),
                message: format!(
                    "malformed detlint annotation (expected `allow(RULE) reason` \
                     or `allow-file(RULE) reason`): `{}`",
                    rest.trim()
                ),
            });
            continue;
        };
        let close = match body.find(')') {
            Some(c) => c,
            None => {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: lineno,
                    rule: "DLINT".to_string(),
                    message: "malformed detlint annotation: missing `)`".to_string(),
                });
                continue;
            }
        };
        let rule = body[..close].trim().to_string();
        if !RULE_IDS.contains(&rule.as_str()) {
            findings.push(Finding {
                file: rel.to_string(),
                line: lineno,
                rule: "DLINT".to_string(),
                message: format!("unknown rule `{rule}` in detlint annotation"),
            });
            continue;
        }
        let reason = body[close + 1..].trim();
        if reason.is_empty() {
            findings.push(Finding {
                file: rel.to_string(),
                line: lineno,
                rule: "DLINT".to_string(),
                message: format!(
                    "detlint allow({rule}) annotation requires a non-empty reason"
                ),
            });
            continue;
        }
        if file_scope {
            allows.file.insert(rule);
        } else {
            // Same-line annotation if the line has code; otherwise the
            // annotation targets the next non-blank code line.
            let mut target = lineno;
            if m.code[idx].trim().is_empty() {
                for (j, code) in m.code.iter().enumerate().skip(idx + 1) {
                    if !code.trim().is_empty() {
                        target = j + 1;
                        break;
                    }
                }
            }
            allows.line.entry(target).or_default().insert(rule);
        }
    }
    allows
}

/// Path components of a `/`-separated relative path.
fn comps(rel: &str) -> Vec<&str> {
    rel.split('/').filter(|c| !c.is_empty()).collect()
}

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    comps(rel).iter().any(|c| dirs.contains(c))
}

fn is_fluid_rs(rel: &str) -> bool {
    let c = comps(rel);
    c.len() >= 2 && c[c.len() - 2] == "engine" && c[c.len() - 1] == "fluid.rs"
}

/// Registered hash-container binding names: `name: …HashMap<…>` /
/// `name: …HashSet<…>` (let bindings, struct fields, fn params) and
/// `name = HashMap::new()`-style initializers.
fn hash_names(m: &Masked) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in &m.code {
        for needle in ["HashMap", "HashSet"] {
            for p in token_positions(line, needle) {
                if let Some(name) = binder_before(line, p) {
                    names.insert(name);
                }
            }
        }
    }
    names
}

/// Walk backwards from a type-position `p` over type-ish characters to
/// the binding `:` (or initializer `=`), then extract the identifier.
fn binder_before(line: &str, p: usize) -> Option<String> {
    let b = line.as_bytes();
    let mut q = p as isize - 1;
    while q >= 0 {
        let ch = b[q as usize];
        if ch == b':' {
            if q > 0 && b[q as usize - 1] == b':' {
                q -= 2; // `::` path segment — keep walking left
                continue;
            }
            return ident_ending_at(line, q as usize);
        } else if ch == b'=' {
            // Reject `==`, `<=`, `=>` partners.
            if q > 0 && matches!(b[q as usize - 1], b'=' | b'<' | b'>' | b'!') {
                return None;
            }
            return ident_ending_at(line, q as usize);
        } else if is_word_b(ch)
            || matches!(ch, b'<' | b'>' | b',' | b'&' | b'\'' | b' ' | b'\t' | b'[' | b']')
        {
            q -= 1;
        } else {
            return None;
        }
    }
    None
}

/// Identifier whose last char sits immediately (modulo spaces) before
/// byte offset `end` in `line`.
fn ident_ending_at(line: &str, end: usize) -> Option<String> {
    let b = line.as_bytes();
    let mut e = end as isize - 1;
    while e >= 0 && (b[e as usize] == b' ' || b[e as usize] == b'\t') {
        e -= 1;
    }
    let stop = e;
    while e >= 0 && is_word_b(b[e as usize]) {
        e -= 1;
    }
    if e == stop {
        return None;
    }
    let name = &line[(e + 1) as usize..=stop as usize];
    if name.is_empty() || name.as_bytes()[0].is_ascii_digit() {
        return None;
    }
    match name {
        "mut" | "let" | "pub" | "ref" => None,
        _ => Some(name.to_string()),
    }
}

const D001_METHODS: [&str; 7] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".drain(",
];

/// D001: hash-container iteration in order-sensitive modules.
fn rule_d001(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    if !in_dirs(rel, &["engine", "optimizer", "experiments"]) {
        return;
    }
    let names = hash_names(m);
    if names.is_empty() {
        return;
    }
    for (idx, line) in m.code.iter().enumerate() {
        for name in &names {
            let mut hit = false;
            for p in token_positions(line, name) {
                let after = &line[p + name.len()..];
                if D001_METHODS.iter().any(|mth| after.starts_with(mth)) {
                    hit = true;
                } else if after.trim().is_empty() {
                    // Multiline method chain: `self.name` at end of line,
                    // `.iter()` on the next code line.
                    if let Some(next) = m.code[idx + 1..].iter().find(|l| !l.trim().is_empty())
                    {
                        let nt = next.trim_start();
                        if D001_METHODS.iter().any(|mth| nt.starts_with(mth)) {
                            hit = true;
                        }
                    }
                }
            }
            if !hit {
                // `for … in &name` / `for … in name` (move iteration).
                for p in token_positions(line, "in") {
                    let mut rest = line[p + 2..].trim_start();
                    rest = rest.strip_prefix('&').unwrap_or(rest);
                    rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
                    rest = rest.strip_prefix("self.").unwrap_or(rest);
                    if let Some(tail) = rest.strip_prefix(name.as_str()) {
                        let nb = tail.as_bytes().first().copied();
                        // A following `.` or `(` means a method chain or
                        // call — handled (or not a direct map iteration).
                        if nb.map_or(true, |c| !is_word_b(c) && c != b'.' && c != b'(') {
                            hit = true;
                        }
                    }
                }
            }
            if hit && !sorted_nearby(m, idx) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "D001".to_string(),
                    message: format!(
                        "iteration over hash container `{name}` may leak nondeterministic \
                         order; sort the result, use BTreeMap/BTreeSet, or annotate \
                         `// detlint: allow(D001) <reason>`"
                    ),
                });
            }
        }
    }
}

/// The collect-then-sort escape: an explicit sort (or BTree
/// re-collection) within [`D001_SORT_WINDOW`] lines of the iteration.
fn sorted_nearby(m: &Masked, idx: usize) -> bool {
    let end = (idx + D001_SORT_WINDOW + 1).min(m.code.len());
    m.code[idx..end]
        .iter()
        .any(|l| l.contains(".sort") || l.contains("BTree"))
}

const D002_OPENERS: [&str; 5] = [
    "sort_by",
    "sort_unstable_by",
    "max_by",
    "min_by",
    "binary_search_by",
];

/// D002: `partial_cmp` inside a comparator-call's parentheses.
fn rule_d002(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    let all = m.code.join("\n");
    // Byte offset of each line start, for offset → line mapping.
    let mut starts = vec![0usize];
    for (i, b) in all.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    let line_of = |off: usize| match starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    };
    let bytes = all.as_bytes();
    for opener in D002_OPENERS {
        for p in token_positions(&all, opener) {
            // Find the call's `(`, allowing whitespace (incl. newlines).
            let mut j = p + opener.len();
            while j < bytes.len() && (bytes[j] as char).is_whitespace() {
                j += 1;
            }
            if j >= bytes.len() || bytes[j] != b'(' {
                continue;
            }
            // Walk to the matching `)` (strings are already blanked).
            let start = j;
            let mut depth = 0i32;
            while j < bytes.len() {
                match bytes[j] {
                    b'(' => depth += 1,
                    b')' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            let span = &all[start..j.min(bytes.len())];
            for q in token_positions(span, "partial_cmp") {
                out.push(Finding {
                    file: rel.to_string(),
                    line: line_of(start + q),
                    rule: "D002".to_string(),
                    message: format!(
                        "`partial_cmp` inside `{opener}` comparator; use `total_cmp` \
                         for a NaN-safe total order"
                    ),
                });
            }
        }
    }
}

/// D003: wall-clock time sources in the deterministic core.
fn rule_d003(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    if !in_dirs(rel, &["engine", "model", "solver", "optimizer"]) {
        return;
    }
    // Bench/timing files measure wall-clock by design.
    let c = comps(rel);
    if c.iter().any(|s| *s == "benches") || c.last().map_or(false, |f| f.contains("bench")) {
        return;
    }
    for (idx, line) in m.code.iter().enumerate() {
        for token in ["Instant::now", "SystemTime", "std::time"] {
            if !token_positions(line, token).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "D003".to_string(),
                    message: format!(
                        "wall-clock time source `{token}` in the deterministic core; \
                         use virtual time, or move timing to bench/experiment code"
                    ),
                });
                break; // one report per line
            }
        }
    }
}

/// D004: ambient (unseeded) randomness anywhere.
fn rule_d004(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    for (idx, line) in m.code.iter().enumerate() {
        for token in ["thread_rng", "rand::random", "RandomState"] {
            if !token_positions(line, token).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "D004".to_string(),
                    message: format!(
                        "ambient randomness `{token}`; every draw must flow from an \
                         explicit seed through util::rng::Pcg64"
                    ),
                });
                break;
            }
        }
    }
}

/// D005: thread creation outside the sharded fluid re-solve.
fn rule_d005(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    if is_fluid_rs(rel) {
        return;
    }
    for (idx, line) in m.code.iter().enumerate() {
        for token in ["std::thread", "thread::spawn", ".spawn("] {
            if !token_positions(line, token).is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "D005".to_string(),
                    message: format!(
                        "thread creation `{token}` outside engine/fluid.rs; \
                         parallelism is confined to the sharded fluid re-solve"
                    ),
                });
                break;
            }
        }
    }
}

const D006_SUFFIXES: [&str; 3] = ["_bytes_delivered", "_repushed", "_replayed"];

/// D006: `+=` into an exact-conservation counter without an adjacent
/// `exact` comment.
fn rule_d006(rel: &str, m: &Masked, out: &mut Vec<Finding>) {
    for (idx, line) in m.code.iter().enumerate() {
        for p in token_positions(line, "+=") {
            let b = line.as_bytes();
            let mut e = p as isize - 1;
            while e >= 0 && (b[e as usize] == b' ' || b[e as usize] == b'\t') {
                e -= 1;
            }
            let stop = e;
            while e >= 0 && is_word_b(b[e as usize]) {
                e -= 1;
            }
            if e == stop {
                continue;
            }
            let name = &line[(e + 1) as usize..=stop as usize];
            if !D006_SUFFIXES.iter().any(|s| name.ends_with(s)) {
                continue;
            }
            let lo = idx.saturating_sub(D006_COMMENT_WINDOW);
            let has_exact = m.comment[lo..=idx]
                .iter()
                .any(|c| c.to_ascii_lowercase().contains("exact"));
            if !has_exact {
                out.push(Finding {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "D006".to_string(),
                    message: format!(
                        "`+=` into exact-conservation counter `{name}` without an \
                         adjacent `exact` comment; byte credits must stay exact \
                         (integers carried in f64)"
                    ),
                });
            }
        }
    }
}

/// Analyze one file's source. `rel` is the path used both for display
/// and for rule scoping (its components decide D001/D003/D005 scope).
pub fn analyze_source(rel: &str, text: &str, analysis: &mut Analysis) {
    let m = mask_source(text);
    let mut raw: Vec<Finding> = Vec::new();
    let allows = parse_annotations(rel, &m, &mut raw);
    // DLINT findings are never suppressible; collect them apart.
    let mut findings: Vec<Finding> = raw;
    let mut candidates: Vec<Finding> = Vec::new();
    rule_d001(rel, &m, &mut candidates);
    rule_d002(rel, &m, &mut candidates);
    rule_d003(rel, &m, &mut candidates);
    rule_d004(rel, &m, &mut candidates);
    rule_d005(rel, &m, &mut candidates);
    rule_d006(rel, &m, &mut candidates);
    for f in candidates {
        let allowed = allows.file.contains(&f.rule)
            || allows
                .line
                .get(&f.line)
                .map_or(false, |set| set.contains(&f.rule));
        if allowed {
            analysis.suppressed += 1;
        } else {
            findings.push(f);
        }
    }
    findings.sort();
    findings.dedup();
    analysis.files += 1;
    analysis.findings.extend(findings);
}

/// Recursively collect `.rs` files under `dir`, as `/`-separated paths
/// relative to `dir`, in sorted (deterministic) order.
pub fn collect_rs_files(dir: &Path) -> std::io::Result<Vec<String>> {
    let mut out = Vec::new();
    let mut stack = vec![String::new()];
    while let Some(prefix) = stack.pop() {
        let full = if prefix.is_empty() {
            dir.to_path_buf()
        } else {
            dir.join(&prefix)
        };
        let mut entries: Vec<(String, bool)> = Vec::new();
        for entry in fs::read_dir(&full)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let is_dir = entry.file_type()?.is_dir();
            entries.push((name, is_dir));
        }
        entries.sort();
        for (name, is_dir) in entries {
            let rel = if prefix.is_empty() {
                name.clone()
            } else {
                format!("{prefix}/{name}")
            };
            if is_dir {
                stack.push(rel);
            } else if name.ends_with(".rs") {
                out.push(rel);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Analyze every `.rs` file under `root`. `display_prefix` (when
/// non-empty) is prepended to each relative path in diagnostics; rule
/// scoping always uses the path relative to `root`.
pub fn analyze_tree(
    root: &Path,
    display_prefix: &str,
    analysis: &mut Analysis,
) -> std::io::Result<()> {
    for rel in collect_rs_files(root)? {
        let text = fs::read_to_string(root.join(&rel))?;
        let before = analysis.findings.len();
        analyze_source(&rel, &text, analysis);
        if !display_prefix.is_empty() {
            let pfx = display_prefix.trim_end_matches('/');
            for f in &mut analysis.findings[before..] {
                f.file = format!("{pfx}/{}", f.file);
            }
        }
    }
    analysis.findings.sort();
    analysis.findings.dedup();
    Ok(())
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine-readable report (stable schema, see
/// `docs/LINTS.md`). The Python mirror emits the same shape.
pub fn render_json(a: &Analysis) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"version\":1,\"files\":{},\"suppressed\":{},\"findings\":[",
        a.files, a.suppressed
    ));
    for (i, f) in a.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\"}}",
            json_escape(&f.file),
            f.line,
            json_escape(&f.rule),
            json_escape(&f.message)
        ));
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masker_blanks_strings_and_comments() {
        let m = mask_source("let x = \"HashMap.iter()\"; // HashMap\nlet y = 1;\n");
        assert!(!m.code[0].contains("HashMap"));
        assert!(m.comment[0].contains("HashMap"));
        assert!(m.code[1].contains("let y = 1;"));
    }

    #[test]
    fn masker_handles_lifetimes_and_chars() {
        let m = mask_source("fn f<'a>(x: &'a str) -> char { 'x' }\n");
        assert!(m.code[0].contains("fn f<'a>(x: &'a str)"));
        assert!(!m.code[0].contains("'x'"));
    }

    #[test]
    fn masker_handles_raw_strings() {
        let m = mask_source("let r = r#\"thread_rng\"#; let k = r;\n");
        assert!(!m.code[0].contains("thread_rng"));
        assert!(m.code[0].contains("let k = r;"));
    }

    #[test]
    fn masker_handles_nested_block_comments() {
        let m = mask_source("/* a /* b */ still comment */ let z = 2;\n");
        assert!(m.code[0].contains("let z = 2;"));
        assert!(!m.code[0].contains("still"));
    }

    #[test]
    fn token_positions_respect_word_boundaries() {
        assert_eq!(token_positions("sort_by_key(x)", "sort_by"), Vec::<usize>::new());
        assert_eq!(token_positions("xs.sort_by(c)", "sort_by"), vec![3]);
        assert_eq!(token_positions("pending_parts += 1", "pending"), Vec::<usize>::new());
    }

    #[test]
    fn d002_flags_partial_cmp_in_comparator_only() {
        let bad = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let good = "impl O { fn cmp(&self, o: &O) -> Ordering {\n\
                    self.v.partial_cmp(&o.v).unwrap_or(Ordering::Equal) } }\n";
        let mut a = Analysis::default();
        analyze_source("solver/x.rs", bad, &mut a);
        assert_eq!(a.findings.len(), 1);
        assert_eq!(a.findings[0].rule, "D002");
        let mut b = Analysis::default();
        analyze_source("solver/y.rs", good, &mut b);
        assert!(b.findings.is_empty(), "{:?}", b.findings);
    }

    #[test]
    fn d001_sort_escape_and_scope() {
        let src = "use std::collections::HashMap;\n\
                   pub fn f(m: &HashMap<u32, u32>) -> Vec<u32> {\n\
                   let mut ks: Vec<u32> = m.keys().copied().collect();\n\
                   ks.sort();\n\
                   ks\n}\n";
        let mut a = Analysis::default();
        analyze_source("engine/x.rs", src, &mut a);
        assert!(a.findings.is_empty(), "{:?}", a.findings);
        // Same source without the sort → finding.
        let src2 = src.replace("ks.sort();\n", "");
        let mut b = Analysis::default();
        analyze_source("engine/x.rs", &src2, &mut b);
        assert_eq!(b.findings.len(), 1);
        assert_eq!(b.findings[0].rule, "D001");
        // Out of scope → clean either way.
        let mut c = Analysis::default();
        analyze_source("util/x.rs", &src2, &mut c);
        assert!(c.findings.is_empty());
    }

    #[test]
    fn annotation_requires_reason_and_never_suppresses_when_malformed() {
        let src = "use std::collections::HashMap;\n\
                   pub fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   // detlint: allow(D001)\n\
                   m.values().sum()\n}\n";
        let mut a = Analysis::default();
        analyze_source("engine/x.rs", src, &mut a);
        let rules: Vec<&str> = a.findings.iter().map(|f| f.rule.as_str()).collect();
        assert!(rules.contains(&"DLINT"), "{rules:?}");
        assert!(rules.contains(&"D001"), "{rules:?}");
        let fixed = src.replace("allow(D001)", "allow(D001) order-free commutative sum");
        let mut b = Analysis::default();
        analyze_source("engine/x.rs", &fixed, &mut b);
        assert!(b.findings.is_empty(), "{:?}", b.findings);
        assert_eq!(b.suppressed, 1);
    }

    #[test]
    fn d006_exact_comment_window() {
        let bad = "fn f(m: &mut M, b: f64) { m.push_bytes_repushed += b; }\n";
        let good = "fn f(m: &mut M, b: f64) {\n\
                    // Exact: integer bytes in f64.\n\
                    m.push_bytes_repushed += b;\n}\n";
        let mut a = Analysis::default();
        analyze_source("engine/x.rs", bad, &mut a);
        assert_eq!(a.findings.len(), 1);
        assert_eq!(a.findings[0].rule, "D006");
        let mut b = Analysis::default();
        analyze_source("engine/x.rs", good, &mut b);
        assert!(b.findings.is_empty(), "{:?}", b.findings);
    }

    #[test]
    fn json_escapes_and_shape() {
        let mut a = Analysis::default();
        a.files = 1;
        a.findings.push(Finding {
            file: "a\"b.rs".into(),
            line: 3,
            rule: "D004".into(),
            message: "back\\slash".into(),
        });
        let j = render_json(&a);
        assert!(j.starts_with("{\"version\":1,"));
        assert!(j.contains("a\\\"b.rs"));
        assert!(j.contains("back\\\\slash"));
        assert!(j.ends_with("]}\n"));
    }
}
