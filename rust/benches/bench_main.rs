//! `cargo bench` — micro/macro benchmarks over the whole stack
//! (criterion is unavailable offline; `mrperf::util::bench` provides the
//! harness: warmup, auto-sized iteration counts, mean/p50/p95).
//!
//! Groups:
//! * `model/*`   — makespan-model evaluation hot path (L3).
//! * `solver/*`  — LP solves (IPM + simplex) at paper scale.
//! * `optimizer/*` — full plan optimizations per scheme (one per paper
//!   comparison — these are the end-to-end units behind Figs 5–8).
//! * `engine/*`  — emulated-testbed job execution (Fig 9 unit), plus the
//!   `engine/scale_*` sweep on generated 64/128/256-node topologies
//!   (ISSUE 1 acceptance: the 256-node job must complete in < 1 s).
//! * `runtime/*` — PJRT artifact dispatch (L1/L2 integration), when
//!   artifacts are present.
//!
//! Filter with `cargo bench -- <substring>`.

use std::time::Duration;

use mrperf::apps::SyntheticApp;
use mrperf::engine::job::JobConfig;
use mrperf::engine::run_job;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{makespan, AppModel};
use mrperf::model::plan::Plan;
use mrperf::model::smooth::smooth_makespan_plan;
use mrperf::optimizer::lp_build::{build_lp_x, build_lp_y, Objective};
use mrperf::optimizer::perf::{add_scale_ab_benches, add_scale_headline_benches};
use mrperf::optimizer::{AlternatingLp, E2ePush, Myopic, PlanOptimizer};
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::bench::{black_box, BenchConfig, BenchSuite};
use mrperf::util::rng::Pcg64;

fn main() {
    let cfg = BenchConfig {
        warmup: Duration::from_millis(300),
        min_iters: 5,
        max_iters: 5_000,
        target_time: Duration::from_secs(2),
    };
    let mut suite = BenchSuite::new(cfg);

    let topo = build_env(EnvKind::Global8);
    let app = AppModel::new(1.0);
    let bc = BarrierConfig::ALL_GLOBAL;
    let mut rng = Pcg64::new(1);
    let plans: Vec<Plan> = (0..64).map(|_| Plan::random(8, 8, 8, &mut rng)).collect();

    // ---- model ----------------------------------------------------------
    suite.bench_items("model/makespan_eval_8x8x8_batch64", 64.0, || {
        let mut acc = 0.0;
        for p in &plans {
            acc += makespan(&topo, app, bc, p);
        }
        black_box(acc)
    });
    suite.bench_items("model/smooth_makespan_8x8x8_batch64", 64.0, || {
        let mut acc = 0.0;
        for p in &plans {
            acc += smooth_makespan_plan(&topo, app, bc, p, 1e-3);
        }
        black_box(acc)
    });

    // ---- solver ---------------------------------------------------------
    let y = vec![0.125f64; 8];
    suite.bench("solver/ipm_lp_x_8x8x8", || {
        let (lp, _) = build_lp_x(&topo, app, bc, &y, Objective::Makespan);
        black_box(mrperf::solver::ipm::solve(&lp))
    });
    suite.bench("solver/simplex_lp_x_8x8x8", || {
        let (lp, _) = build_lp_x(&topo, app, bc, &y, Objective::Makespan);
        black_box(mrperf::solver::simplex::solve(&lp))
    });

    // ---- optimizers (the units behind Figs 5–8) --------------------------
    suite.bench("optimizer/myopic_multi_8dc", || {
        black_box(Myopic.optimize(&topo, app, bc))
    });
    suite.bench("optimizer/e2e_push_8dc", || {
        black_box(E2ePush.optimize(&topo, app, bc))
    });
    suite.bench("optimizer/e2e_multi_alternating_8dc", || {
        let opt = AlternatingLp { random_starts: 0, max_rounds: 8, ..Default::default() };
        black_box(opt.optimize(&topo, app, bc))
    });

    // ---- engine (Fig 9 unit) ---------------------------------------------
    let inputs = synthetic_inputs(8, 1 << 19, 3);
    let total_bytes: f64 = inputs.iter().flatten().map(|r| r.size() as f64).sum();
    let plan = Plan::uniform(8, 8, 8);
    let sapp = SyntheticApp::new(1.0);
    suite.bench_items("engine/synthetic_job_4MiB_8dc", total_bytes, || {
        black_box(
            run_job(&topo, &plan, &sapp, &JobConfig::default(), &inputs)
                .metrics
                .makespan,
        )
    });

    // ---- engine scale sweep (generated topologies) ------------------------
    // ISSUE 1 acceptance: a 256-node synthetic job must simulate in < 1 s.
    for &nodes in &[64usize, 128, 256] {
        let stopo = generate_kind(ScaleKind::HierarchicalWan, nodes, 7);
        let splan = Plan::local_push(&stopo);
        let sinputs = synthetic_inputs(stopo.n_sources(), 2_000, 11);
        let scale_app = SyntheticApp::new(1.0);
        suite.bench(&format!("engine/scale_{nodes}node_hier_wan_job"), || {
            black_box(
                run_job(&stopo, &splan, &scale_app, &JobConfig::default(), &sinputs)
                    .metrics
                    .makespan,
            )
        });
    }

    // ---- ISSUE 7 engine gate: 4096 nodes, sub-second ----------------------
    // The incremental component re-solve is what makes this affordable:
    // pre-PR every event re-filled all ~12k resources; now only the dirty
    // component refills. One-shot (no warmup) so the gate measures a cold
    // run, same as a user invoking `mrperf run --gen hier-wan:4096`.
    let issue7_cfg = BenchConfig {
        warmup: Duration::ZERO,
        min_iters: 1,
        max_iters: 1,
        target_time: Duration::ZERO,
    };
    let mut issue7 = BenchSuite::new(issue7_cfg);
    {
        let gtopo = generate_kind(ScaleKind::HierarchicalWan, 4096, 7);
        let gplan = Plan::local_push(&gtopo);
        let ginputs = synthetic_inputs(gtopo.n_sources(), 2_000, 11);
        let gapp = SyntheticApp::new(1.0);
        issue7.bench("engine/scale_4096node_hier_wan_job", || {
            black_box(
                run_job(&gtopo, &gplan, &gapp, &JobConfig::default(), &ginputs)
                    .metrics
                    .makespan,
            )
        });
    }

    // ---- ISSUE 7 solver gate: devex (bounded) vs Dantzig (materialized) ---
    // A/B the hier-wan:256 plan LP through the pre-PR path — Dantzig
    // pricing on the LP with single-variable rows materialized — and the
    // new path — devex pricing on the implicit-bound LP. Three iterations
    // each (the solves are deterministic; this just smooths scheduler
    // noise on a one-shot measurement).
    let devex_cfg = BenchConfig {
        warmup: Duration::ZERO,
        min_iters: 3,
        max_iters: 3,
        target_time: Duration::ZERO,
    };
    let mut devex_suite = BenchSuite::new(devex_cfg);
    {
        use mrperf::solver::revised::solve_warm_pricing;
        use mrperf::solver::Pricing;
        let t256 = generate_kind(ScaleKind::HierarchicalWan, 256, 7);
        let y256 = vec![1.0 / t256.n_reducers() as f64; t256.n_reducers()];
        let (lp256, _) = build_lp_x(&t256, app, BarrierConfig::HADOOP, &y256, Objective::Makespan);
        let lp256_rows = lp256.materialize_bounds();
        devex_suite.bench("solver/lp_x_256node_devex_bounded", || {
            black_box(solve_warm_pricing(&lp256, None, Pricing::Devex))
        });
        devex_suite.bench("solver/lp_x_256node_dantzig_materialized", || {
            black_box(solve_warm_pricing(&lp256_rows, None, Pricing::Dantzig))
        });
    }

    // ---- optimizer scale paths (ISSUE 2) ----------------------------------
    // A/B of the pre-PR optimizer paths against the sparse/analytic ones
    // at 64 nodes (single iteration — the baseline is deliberately the
    // slow path), plus the 256-node acceptance headline. The assertions
    // at the bottom are the ISSUE 2 acceptance criteria: ≥10× at 64
    // nodes, <30 s for a hier-wan:256 plan.
    let oneshot_cfg = BenchConfig {
        warmup: Duration::ZERO,
        min_iters: 1,
        max_iters: 1,
        target_time: Duration::ZERO,
    };
    let mut oneshot = BenchSuite::new(oneshot_cfg);
    let ab_pairs = add_scale_ab_benches(&mut oneshot, 64);
    let headline = add_scale_headline_benches(&mut oneshot);

    // ---- runtime (PJRT) ---------------------------------------------------
    if let Ok(planner) = mrperf::runtime::ArtifactPlanner::load(8, 8, 8) {
        suite.bench("runtime/artifact_optimize_8x8x8_p16", || {
            black_box(planner.optimize(&topo, app, bc).unwrap())
        });
    } else {
        eprintln!("(skipping runtime/* benches: run `make artifacts` first)");
    }

    suite.report();
    oneshot.report();
    issue7.report();
    devex_suite.report();

    // Surface the ISSUE 1 scale target explicitly.
    if let Some(r) = suite
        .results()
        .iter()
        .find(|r| r.name.contains("scale_256node"))
    {
        let ok = r.mean < Duration::from_secs(1);
        println!(
            "\nscale target: 256-node run_job mean {:?} — {}",
            r.mean,
            if ok { "PASS (< 1 s)" } else { "FAIL (>= 1 s)" }
        );
    }

    // ---- ISSUE 2 acceptance: ≥10× speedup at 64 nodes, <30 s at 256 -------
    let find = |name: &str| oneshot.results().iter().find(|r| r.name == name);
    for (label, new_name, old_name) in &ab_pairs {
        if let (Some(new), Some(old)) = (find(new_name), find(old_name)) {
            let ratio = old.mean.as_secs_f64() / new.mean.as_secs_f64().max(1e-12);
            println!(
                "optimizer scale target: {label} 64-node speedup {ratio:.1}x — {}",
                if ratio >= 10.0 { "PASS (>= 10x)" } else { "FAIL (< 10x)" }
            );
            assert!(
                ratio >= 10.0,
                "{label}: {ratio:.1}x speedup over the pre-PR path is below the 10x bar"
            );
        }
    }
    for name in &headline {
        if let Some(r) = find(name) {
            let ok = r.mean < Duration::from_secs(30);
            println!(
                "optimizer scale target: {name} mean {:?} — {}",
                r.mean,
                if ok { "PASS (< 30 s)" } else { "FAIL (>= 30 s)" }
            );
            assert!(ok, "{name} exceeded the 30 s acceptance bound");
        }
    }

    // ---- ISSUE 7 acceptance gates ------------------------------------------
    // (1) 4096-node engine run stays sub-second (cold, single shot).
    let g4096 = issue7
        .results()
        .iter()
        .find(|r| r.name.contains("scale_4096node"))
        .expect("4096-node gate bench must have run");
    let ok = g4096.mean < Duration::from_secs(1);
    println!(
        "engine scale target: 4096-node run_job {:?} — {}",
        g4096.mean,
        if ok { "PASS (< 1 s)" } else { "FAIL (>= 1 s)" }
    );
    assert!(ok, "4096-node hier-wan run took {:?} (gate: < 1 s)", g4096.mean);

    // (2) Implicit bounds strictly cut the plan-LP row count on every
    // paper environment: materializing the bounds back into explicit
    // rows must always grow the (x-LP + y-LP) total — i.e. at least one
    // single-variable constraint per env now lives in the bound vectors
    // instead of the row list.
    for env in EnvKind::all() {
        let t = build_env(env);
        let (s, m, r) = (t.n_sources(), t.n_mappers(), t.n_reducers());
        let y0 = vec![1.0 / r as f64; r];
        let (lpx, _) = build_lp_x(&t, app, BarrierConfig::HADOOP, &y0, Objective::Makespan);
        let (lpy, _) = build_lp_y(
            &t,
            app,
            BarrierConfig::HADOOP,
            &Plan::uniform(s, m, r).x,
            Objective::Makespan,
        );
        let bounded = lpx.n_rows() + lpy.n_rows();
        let materialized =
            lpx.materialize_bounds().n_rows() + lpy.materialize_bounds().n_rows();
        println!(
            "row-count target: {} plan LPs {bounded} rows bounded vs {materialized} \
             materialized — {}",
            t.name,
            if materialized > bounded { "PASS (reduced)" } else { "FAIL (no cut)" }
        );
        assert!(
            materialized > bounded,
            "{}: implicit bounds must strictly reduce plan-LP rows \
             ({bounded} bounded vs {materialized} materialized)",
            t.name
        );
    }

    // (3) Devex pricing on the implicit-bound LP beats the pre-PR path
    // (Dantzig pricing, bounds materialized as rows) by ≥ 2× on the
    // hier-wan:256 plan LP.
    let dfind = |name: &str| {
        devex_suite
            .results()
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("devex gate bench '{name}' must have run"))
            .mean
            .as_secs_f64()
    };
    let devex = dfind("solver/lp_x_256node_devex_bounded");
    let dantzig = dfind("solver/lp_x_256node_dantzig_materialized");
    let ratio = dantzig / devex.max(1e-12);
    println!(
        "solver pricing target: hier-wan:256 x-LP devex {ratio:.1}x over Dantzig — {}",
        if ratio >= 2.0 { "PASS (>= 2x)" } else { "FAIL (< 2x)" }
    );
    assert!(
        ratio >= 2.0,
        "devex pricing only {ratio:.1}x over the Dantzig/materialized path (gate: >= 2x)"
    );
}
