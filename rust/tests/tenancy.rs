//! Multi-tenant stream-layer properties (ISSUE 6):
//!
//! * **single-job equivalence** — a one-job stream arriving at t = 0
//!   reproduces `run_job`'s metrics bit-for-bit, with and without a
//!   platform dynamics trace (the stream plumbing must not perturb the
//!   arithmetic);
//! * **determinism** — the same job stream under the same policy gives
//!   bit-identical per-job metrics and outcome times across runs;
//! * **per-job conservation** — every concurrent job conserves its own
//!   push and shuffle bytes exactly, including under injected failures;
//! * **policy semantics** — FIFO serializes the jobs on the shared
//!   network while fair-share overlaps them (and the contention from
//!   overlap visibly stretches each job past its standalone time);
//! * **validation** — malformed streams are rejected with CLI-grade
//!   messages before any simulation runs.

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynProfile, ScenarioTrace, TraceShape};
use mrperf::engine::job::JobConfig;
use mrperf::engine::tenancy::{run_stream, run_stream_with_recovery, StreamJob};
use mrperf::engine::{run_job, stream_policy, JobMetrics, RecoveryOpts, Record};
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::plan::Plan;
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::Topology;
use mrperf::util::qcheck::{ensure, qcheck, Config};

/// Bit-exact signature of every metric field (floats by bit pattern).
/// `coordinator_restarts` and `replans_skipped` are deliberately
/// excluded: both are provenance (crashes survived, re-solve
/// evaluations declined — a resume re-evaluates one boundary), and the
/// checkpoint/resume invariant is exactly that everything else matches
/// bit for bit. Accepted replans and the migration counters ARE part of
/// the identity: a resumed replanning run must replay them exactly.
fn sig(m: &JobMetrics) -> String {
    format!(
        "{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        m.makespan.to_bits(),
        m.push_end.to_bits(),
        m.map_end.to_bits(),
        m.shuffle_end.to_bits(),
        m.push_bytes.to_bits(),
        m.shuffle_bytes.to_bits(),
        m.output_bytes.to_bits(),
        m.reduce_bytes_replayed.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_repushed.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.dlq_bytes.to_bits(),
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.spec_launched,
        m.spec_won,
        m.stolen,
        m.dyn_events,
        m.failures_injected,
        m.tasks_requeued,
        m.reducers_failed,
        m.reduce_ranges_reassigned,
        m.sources_refreshed,
        m.splits_dead_lettered,
        m.ranges_dead_lettered,
        m.input_records,
        m.intermediate_records,
        m.output_records,
        m.replans,
        m.replan_migrated_splits,
        m.replan_migrated_ranges
    )
}

fn setup(seed: u64) -> (Topology, Plan) {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, seed);
    let plan = Plan::local_push(&topo);
    (topo, plan)
}

/// A one-job stream at t = 0 IS the single-job path: every metric
/// matches `run_job` bit for bit, both statically and under a shared
/// failures trace (passed per-job to `run_job`, platform-wide to
/// `run_stream`).
#[test]
fn single_job_stream_is_bit_identical_to_single_job() {
    let (topo, plan) = setup(3);
    let app = SyntheticApp::new(1.0);
    let config = JobConfig::default();
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    let jobs = [StreamJob::new(0.0, &plan, &app, &config, &inputs)];

    let single = run_job(&topo, &plan, &app, &config, &inputs).metrics;
    let mut policy = stream_policy("fifo").unwrap();
    let res = run_stream(&topo, &jobs, policy.as_mut(), None).unwrap();
    let o = &res.jobs[0];
    let m = o.metrics.as_ref().expect("the lone job must complete");
    assert_eq!(sig(&single), sig(m), "single-job stream diverged from run_job");
    assert_eq!(o.started.to_bits(), 0.0f64.to_bits());
    assert_eq!(o.finished.to_bits(), single.makespan.to_bits());
    assert_eq!(res.makespan.to_bits(), single.makespan.to_bits());

    // Same equivalence with a failures trace actually firing mid-run.
    let trace = ScenarioTrace::generate(
        DynProfile::Failures,
        7,
        &TraceShape::of(&topo, single.makespan),
    );
    let dyn_cfg = config.clone().with_dynamics(trace.clone());
    let single_dyn = run_job(&topo, &plan, &app, &dyn_cfg, &inputs).metrics;
    assert!(single_dyn.failures_injected > 0, "trace must actually fire");
    let mut policy = stream_policy("fifo").unwrap();
    let res = run_stream(&topo, &jobs, policy.as_mut(), Some(&trace)).unwrap();
    assert_eq!(
        sig(&single_dyn),
        sig(res.jobs[0].metrics.as_ref().expect("job must complete")),
        "single-job stream diverged from run_job under dynamics"
    );
}

/// Same seed, same stream, same policy → bit-identical per-job metrics
/// and outcome times; fair-share overlaps all three jobs at t = 0.
#[test]
fn same_seed_streams_are_bit_identical() {
    let (topo, plan) = setup(3);
    let app = SyntheticApp::new(1.0);
    let config = JobConfig::default();
    let inputs_a = synthetic_inputs(topo.n_sources(), 1 << 13, 0xA11CE);
    let inputs_b = synthetic_inputs(topo.n_sources(), 1 << 13, 0xB0B);
    // The third arrival lands mid-run of the first two whatever the
    // absolute time scale of this topology is.
    let arr2 = 0.25 * run_job(&topo, &plan, &app, &config, &inputs_a).metrics.makespan;
    assert!(arr2 > 0.0);
    let run = || {
        let jobs = vec![
            StreamJob::new(0.0, &plan, &app, &config, &inputs_a),
            StreamJob::new(0.0, &plan, &app, &config, &inputs_b),
            StreamJob::new(arr2, &plan, &app, &config, &inputs_a),
        ];
        let mut policy = stream_policy("fair-share").unwrap();
        run_stream(&topo, &jobs, policy.as_mut(), None).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    for (i, (x, y)) in a.jobs.iter().zip(&b.jobs).enumerate() {
        assert!(!x.rejected, "job {i} rejected");
        assert_eq!(x.started.to_bits(), y.started.to_bits(), "job {i}");
        assert_eq!(x.finished.to_bits(), y.finished.to_bits(), "job {i}");
        assert_eq!(
            sig(x.metrics.as_ref().unwrap()),
            sig(y.metrics.as_ref().unwrap()),
            "job {i}: stream run is nondeterministic"
        );
    }
    // Fair-share (cap 4) admits every job the moment it arrives, so all
    // three overlap: the first two each take at least their standalone
    // makespan (4 × arr2) under contention, so the third arrives while
    // both still run.
    assert_eq!(a.jobs[0].started, 0.0);
    assert_eq!(a.jobs[1].started, 0.0);
    assert_eq!(a.jobs[2].started.to_bits(), arr2.to_bits());
    assert!(
        a.jobs[2].started < a.jobs[0].finished.min(a.jobs[1].finished),
        "third job must overlap the first two"
    );
}

/// Per-job exact byte conservation with ≥ 2 concurrent jobs under
/// generated failure traces: each executor keeps its own transfer
/// tables, so no byte is lost or cross-credited between tenants.
#[test]
fn concurrent_jobs_conserve_bytes_under_failures() {
    qcheck(Config::default().cases(8), "per-job conservation in a stream", |rng| {
        let (topo, plan) = setup(3);
        let app = SyntheticApp::new(1.0);
        let config = JobConfig::default();
        let inputs_a = synthetic_inputs(topo.n_sources(), 1 << 13, 0xFA11);
        let inputs_b = synthetic_inputs(topo.n_sources(), 1 << 13, 0xFA12);
        let trace_seed = rng.next_u64();
        // A standalone run fixes the horizon: the concurrent stream runs
        // at least as long, so events land while both jobs are active.
        let stat = run_job(&topo, &plan, &app, &config, &inputs_a).metrics;
        let trace = ScenarioTrace::generate(
            DynProfile::Failures,
            trace_seed,
            &TraceShape::of(&topo, stat.makespan),
        );
        let jobs = vec![
            StreamJob::new(0.0, &plan, &app, &config, &inputs_a),
            StreamJob::new(0.0, &plan, &app, &config, &inputs_b),
        ];
        let mut policy = stream_policy("fair-share").unwrap();
        let res = run_stream(&topo, &jobs, policy.as_mut(), Some(&trace))
            .map_err(|e| format!("run_stream: {e}"))?;
        let mut any_failures = false;
        for (i, o) in res.jobs.iter().enumerate() {
            ensure(!o.rejected, format!("job {i} was rejected"))?;
            ensure(o.started == 0.0, format!("job {i} must be admitted at t=0"))?;
            let m = o.metrics.as_ref().expect("completed job carries metrics");
            // Byte counts are integers < 2^53, so the f64 sums are exact
            // and equality is exact.
            ensure(
                m.push_bytes_delivered == m.push_bytes,
                format!(
                    "seed {trace_seed:#x} job {i}: push delivered {} != pushed {}",
                    m.push_bytes_delivered, m.push_bytes
                ),
            )?;
            ensure(
                m.shuffle_bytes_delivered == m.shuffle_bytes,
                format!(
                    "seed {trace_seed:#x} job {i}: shuffle delivered {} != \
                     shuffled {} (replayed {})",
                    m.shuffle_bytes_delivered, m.shuffle_bytes, m.reduce_bytes_replayed
                ),
            )?;
            ensure(
                m.output_records == m.input_records,
                format!(
                    "seed {trace_seed:#x} job {i}: lost records ({} in, {} out)",
                    m.input_records, m.output_records
                ),
            )?;
            any_failures |= m.failures_injected > 0;
        }
        ensure(
            any_failures,
            format!("seed {trace_seed:#x}: no failure landed on any job"),
        )?;
        Ok(())
    });
}

/// Policy semantics on two simultaneous submissions: FIFO admits the
/// second only when the first finishes (and its first job is
/// bit-identical to the standalone run — an idle queue must not perturb
/// the tenant), while fair-share admits both at t = 0 and the shared
/// source NICs stretch the overlapped job past its standalone makespan.
#[test]
fn fifo_serializes_and_fair_share_overlaps() {
    let (topo, plan) = setup(3);
    let app = SyntheticApp::new(1.0);
    let config = JobConfig::default();
    let inputs_a = synthetic_inputs(topo.n_sources(), 1 << 13, 0xA11CE);
    let inputs_b = synthetic_inputs(topo.n_sources(), 1 << 13, 0xB0B);
    let jobs = vec![
        StreamJob::new(0.0, &plan, &app, &config, &inputs_a),
        StreamJob::new(0.0, &plan, &app, &config, &inputs_b),
    ];
    let single = run_job(&topo, &plan, &app, &config, &inputs_a).metrics;

    let mut fifo = stream_policy("fifo").unwrap();
    let f = run_stream(&topo, &jobs, fifo.as_mut(), None).unwrap();
    assert!(!f.jobs[0].rejected && !f.jobs[1].rejected);
    assert_eq!(f.jobs[0].started, 0.0);
    assert_eq!(
        sig(f.jobs[0].metrics.as_ref().unwrap()),
        sig(&single),
        "an idle FIFO queue must not perturb the running tenant"
    );
    assert!(
        f.jobs[1].started >= f.jobs[0].finished,
        "fifo must serialize: second started {} before first finished {}",
        f.jobs[1].started,
        f.jobs[0].finished
    );

    let mut fair = stream_policy("fair-share").unwrap();
    let s = run_stream(&topo, &jobs, fair.as_mut(), None).unwrap();
    assert_eq!(s.jobs[0].started, 0.0);
    assert_eq!(s.jobs[1].started, 0.0, "fair-share must overlap");
    // Both jobs push from the same sources from t = 0, so max-min
    // sharing of every source NIC strictly slows job 0 down vs its
    // standalone run.
    assert!(
        s.jobs[0].finished > single.makespan,
        "overlap must cost job 0 time ({} vs standalone {})",
        s.jobs[0].finished,
        single.makespan
    );
}

/// The fair-share `weight` knob is live (ISSUE 9 satellite): two
/// identical compute-bound jobs submitted together, one at weight 2,
/// and the heavier job finishes strictly first. The mechanism is slot
/// scaling at admission — weight 2 doubles the job's map/reduce slots,
/// so it runs twice as many concurrent map activities on the shared
/// per-node compute resources and max-min fairness gives it twice the
/// aggregate rate.
#[test]
fn weight_two_job_beats_identical_weight_one_job() {
    let (topo, plan) = setup(3);
    // Compute-bound maps + small splits: several tasks per mapper, so
    // the slot count (not the data) is the binding resource.
    let app = SyntheticApp::new(1.0).with_costs(25.0, 1.0);
    let config = JobConfig { split_size: 2 << 10, ..JobConfig::default() };
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 14, 0xD11A);
    let mut heavy = StreamJob::new(0.0, &plan, &app, &config, &inputs);
    heavy.weight = 2.0;
    let jobs = vec![StreamJob::new(0.0, &plan, &app, &config, &inputs), heavy];
    let mut policy = stream_policy("fair-share").unwrap();
    let res = run_stream(&topo, &jobs, policy.as_mut(), None).unwrap();
    let (a, b) = (&res.jobs[0], &res.jobs[1]);
    assert_eq!(a.started, 0.0);
    assert_eq!(b.started, 0.0, "fair-share must admit both at t=0");
    assert!(
        b.finished < a.finished,
        "the weight-2 job ({}) must finish strictly before its weight-1 twin ({})",
        b.finished,
        a.finished
    );
    // Identical work either way: the weight moves time, not bytes.
    let (ma, mb) = (a.metrics.as_ref().unwrap(), b.metrics.as_ref().unwrap());
    assert_eq!(ma.input_records, mb.input_records);
    assert_eq!(ma.output_records, mb.output_records);
    assert_eq!(ma.push_bytes.to_bits(), mb.push_bytes.to_bits());
}

/// Checkpoint/resume under tenancy (ISSUE 9 tentpole): a 3-job stream
/// crashed mid-run resumes from its snapshot and finishes bit-identical
/// to the uninterrupted stream — per-job metrics, outcome times and the
/// stream makespan — with per-job byte conservation intact and the
/// restart recorded in every finished job's provenance counter.
#[test]
fn crashed_stream_resumes_bit_identical() {
    let (topo, plan) = setup(3);
    let app = SyntheticApp::new(1.0);
    let config = JobConfig::default();
    let inputs_a = synthetic_inputs(topo.n_sources(), 1 << 13, 0xA11CE);
    let inputs_b = synthetic_inputs(topo.n_sources(), 1 << 13, 0xB0B);
    let arr2 = 0.25 * run_job(&topo, &plan, &app, &config, &inputs_a).metrics.makespan;
    let jobs = vec![
        StreamJob::new(0.0, &plan, &app, &config, &inputs_a),
        StreamJob::new(0.0, &plan, &app, &config, &inputs_b),
        StreamJob::new(arr2, &plan, &app, &config, &inputs_a),
    ];

    let mut policy = stream_policy("fair-share").unwrap();
    let reference = run_stream(&topo, &jobs, policy.as_mut(), None).unwrap();

    for crash_frac in [0.35, 0.8] {
        let opts = RecoveryOpts {
            checkpoint_every: Some(reference.makespan / 10.0),
            crash_at: Some(reference.makespan * crash_frac),
            ..RecoveryOpts::default()
        };
        let resumed =
            run_stream_with_recovery(&topo, &jobs, policy.as_mut(), None, &opts).unwrap();
        assert_eq!(
            resumed.makespan.to_bits(),
            reference.makespan.to_bits(),
            "crash at {crash_frac}: stream makespan diverged"
        );
        for (i, (r, u)) in resumed.jobs.iter().zip(&reference.jobs).enumerate() {
            assert!(!r.rejected, "crash at {crash_frac}: job {i} rejected");
            assert_eq!(r.started.to_bits(), u.started.to_bits(), "job {i}");
            assert_eq!(r.finished.to_bits(), u.finished.to_bits(), "job {i}");
            let (rm, um) = (r.metrics.as_ref().unwrap(), u.metrics.as_ref().unwrap());
            assert_eq!(
                sig(rm),
                sig(um),
                "crash at {crash_frac}: job {i} diverged after resume"
            );
            assert_eq!(rm.coordinator_restarts, 1, "job {i} must record the restart");
            assert_eq!(um.coordinator_restarts, 0, "reference saw no crash");
            // Per-job conservation survives the crash/restore cycle.
            assert_eq!(rm.push_bytes_delivered.to_bits(), rm.push_bytes.to_bits());
            assert_eq!(
                (rm.shuffle_bytes_delivered + rm.dlq_bytes).to_bits(),
                rm.shuffle_bytes.to_bits()
            );
        }
    }
}

/// Malformed streams are rejected with CLI-grade messages before any
/// simulation state is built.
#[test]
fn stream_validation_rejects_bad_inputs() {
    let (topo, plan) = setup(3);
    let app = SyntheticApp::new(1.0);
    let config = JobConfig::default();
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 10, 1);
    let mut policy = stream_policy("fifo").unwrap();

    let none: Vec<StreamJob> = Vec::new();
    let e = run_stream(&topo, &none, policy.as_mut(), None).unwrap_err();
    assert!(e.contains("empty job stream"), "{e}");

    let mut j = StreamJob::new(f64::NAN, &plan, &app, &config, &inputs);
    let e = run_stream(&topo, std::slice::from_ref(&j), policy.as_mut(), None).unwrap_err();
    assert!(e.contains("arrival"), "{e}");

    j.arrival = 0.0;
    j.weight = 0.0;
    let e = run_stream(&topo, std::slice::from_ref(&j), policy.as_mut(), None).unwrap_err();
    assert!(e.contains("weight"), "{e}");

    let dyn_cfg = config.clone().with_dynamics(ScenarioTrace::empty("none"));
    let j2 = StreamJob::new(0.0, &plan, &app, &dyn_cfg, &inputs);
    let e = run_stream(&topo, std::slice::from_ref(&j2), policy.as_mut(), None).unwrap_err();
    assert!(e.contains("per-job dynamics"), "{e}");

    let short: Vec<Vec<Record>> = Vec::new();
    let j3 = StreamJob::new(0.0, &plan, &app, &config, &short);
    let e = run_stream(&topo, std::slice::from_ref(&j3), policy.as_mut(), None).unwrap_err();
    assert!(e.contains("input vectors"), "{e}");

    assert!(stream_policy("bogus").unwrap_err().contains("stream policy"));
}
