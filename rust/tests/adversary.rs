//! Adversarial-search properties (ISSUE 5 tentpole):
//!
//! * **determinism** — identical `SearchConfig`s give the identical
//!   worst-case trace and bit-identical makespans (the executor oracle
//!   is bit-reproducible, the move set is a fixed function of the
//!   genome, and the RNG only shapes seeded initial candidates);
//! * **adversary ≥ seeded churn** — with the seeded `failures` profile
//!   in the candidate pool, the found trace degrades the plan-local
//!   mode at least as much — and, thanks to the window-extension move,
//!   strictly more;
//! * **budget respected** — the found trace stays within the
//!   perturbation budget (outage count, window length, factor floor).

use mrperf::apps::SyntheticApp;
use mrperf::engine::adversary::{search, PerturbBudget, SearchConfig};
use mrperf::engine::dynamics::{DynEvent, DynProfile, ScenarioTrace, TraceShape, MIN_FACTOR};
use mrperf::engine::job::JobConfig;
use mrperf::engine::run_job;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::plan::Plan;
use mrperf::platform::scale::{generate_kind, ScaleKind};

struct Setup {
    topo: mrperf::platform::Topology,
    plan: Plan,
    inputs: Vec<Vec<mrperf::engine::Record>>,
    app: SyntheticApp,
}

fn setup() -> Setup {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo); // uniform y: every range has mass
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xADF5);
    Setup { topo, plan, inputs, app: SyntheticApp::new(1.0) }
}

fn small_cfg(seed: u64) -> SearchConfig {
    SearchConfig {
        restarts: 2,
        refine_passes: 1,
        ..SearchConfig::new(PerturbBudget::outages(2), seed)
    }
}

/// (a) Same seed → identical trace, bit-identical makespans, same eval
/// count. Different seed → a different search trajectory.
#[test]
fn search_is_deterministic_per_seed() {
    let s = setup();
    let base = JobConfig::optimized();
    let run = |seed: u64| {
        search(&s.topo, &s.plan, &s.app, &base, &s.inputs, &[], &small_cfg(seed)).unwrap()
    };
    let a = run(7);
    let b = run(7);
    assert_eq!(a.trace, b.trace, "same seed must find the same trace");
    assert_eq!(a.worst_makespan.to_bits(), b.worst_makespan.to_bits());
    assert_eq!(a.static_makespan.to_bits(), b.static_makespan.to_bits());
    assert_eq!(a.evals, b.evals);
    assert!(a.evals > 0 && a.worst_makespan >= a.static_makespan);
    let c = run(8);
    // Different seeds draw different candidate pools; the *outcomes* may
    // coincide, but the search must at least be seed-sensitive enough to
    // produce a valid result both times.
    assert!(c.worst_makespan >= c.static_makespan);
}

/// (b) The adversary-found trace degrades plan-local at least as much
/// as the seeded `failures` profile — and strictly more: the profile
/// recovers its reducer victims by 1.15×horizon, while the budget
/// allows a window-extension move the greedy refinement always tries.
#[test]
fn adversary_degrades_plan_local_more_than_seeded_failures() {
    let s = setup();
    let base = JobConfig::optimized();
    let app = &s.app;

    // Seeded random-churn baseline (the churn-experiment idiom: the
    // static plan-local makespan anchors the horizon).
    let stat = run_job(&s.topo, &s.plan, app, &base, &s.inputs).metrics;
    let shape = TraceShape::of(&s.topo, stat.makespan);
    let failures = ScenarioTrace::generate(DynProfile::Failures, 7, &shape);
    let fail_m = run_job(
        &s.topo,
        &s.plan,
        app,
        &base.clone().with_dynamics(failures.clone()),
        &s.inputs,
    )
    .metrics;
    let baseline_deg = fail_m.makespan / stat.makespan - 1.0;
    assert!(fail_m.reducers_failed > 0, "baseline must include a reducer outage");

    // Budget sized to the seeded trace so the import is never clipped.
    let k = failures
        .events()
        .iter()
        .filter(|te| {
            matches!(te.event, DynEvent::MapperFail { .. } | DynEvent::ReducerFail { .. })
        })
        .count();
    let cfg = SearchConfig {
        restarts: 2,
        refine_passes: 1,
        ..SearchConfig::new(PerturbBudget::outages(k.max(1)), 7)
    };
    let res = search(
        &s.topo,
        &s.plan,
        app,
        &base,
        &s.inputs,
        std::slice::from_ref(&failures),
        &cfg,
    )
    .unwrap();
    assert_eq!(res.static_makespan.to_bits(), stat.makespan.to_bits());
    assert!(
        res.degradation() >= baseline_deg,
        "adversary {:+.4} must be ≥ seeded failures {:+.4}",
        res.degradation(),
        baseline_deg
    );
    assert!(
        res.degradation() > baseline_deg,
        "window extension must make the adversary strictly worse \
         ({:+.4} vs {:+.4})",
        res.degradation(),
        baseline_deg
    );

    // The returned trace must reproduce the claimed worst makespan.
    let replay = run_job(
        &s.topo,
        &s.plan,
        app,
        &base.clone().with_dynamics(res.trace.clone()),
        &s.inputs,
    )
    .metrics;
    assert_eq!(replay.makespan.to_bits(), res.worst_makespan.to_bits());
    assert_eq!(replay.output_records, replay.input_records, "adversary lost records");
}

/// (c) Whatever the adversary finds stays within its budget.
#[test]
fn found_trace_respects_budget() {
    let s = setup();
    let base = JobConfig::optimized();
    let budget = PerturbBudget::outages(2);
    let cfg =
        SearchConfig { restarts: 3, refine_passes: 1, ..SearchConfig::new(budget, 11) };
    let res = search(&s.topo, &s.plan, &s.app, &base, &s.inputs, &[], &cfg).unwrap();
    let h = res.static_makespan;

    // Replay the trace against the engine's last-writer-wins liveness
    // semantics (a Fail on a down node and a Recover on an up node are
    // no-ops): every *effective* downtime interval must fit the budget.
    let mut outages = 0usize;
    let mut down_since: Vec<(bool, usize, f64)> = Vec::new();
    for te in res.trace.events() {
        match te.event {
            DynEvent::MapperFail { node } | DynEvent::ReducerFail { node } => {
                let is_red = matches!(te.event, DynEvent::ReducerFail { .. });
                outages += 1;
                if !down_since.iter().any(|&(r, n, _)| r == is_red && n == node) {
                    down_since.push((is_red, node, te.time));
                }
            }
            DynEvent::MapperRecover { node } | DynEvent::ReducerRecover { node } => {
                let is_red = matches!(te.event, DynEvent::ReducerRecover { .. });
                if let Some(pos) =
                    down_since.iter().position(|&(r, n, _)| r == is_red && n == node)
                {
                    let (_, _, t0) = down_since.remove(pos);
                    assert!(
                        te.time - t0 <= budget.max_window_frac * h * (1.0 + 1e-9),
                        "effective outage window {} exceeds the budget",
                        te.time - t0
                    );
                }
            }
            DynEvent::WanScale { factor } | DynEvent::ClusterLinkScale { factor, .. } => {
                assert!(
                    factor >= budget.min_link_factor - 1e-12 || factor == 1.0,
                    "factor {factor} below the budget floor"
                );
                assert!(factor >= MIN_FACTOR);
            }
            _ => panic!("adversary emitted an out-of-vocabulary event {:?}", te.event),
        }
    }
    assert!(outages <= budget.max_outages, "{outages} outages exceed the budget");
    assert!(down_since.is_empty(), "every adversarial outage must recover");

    // Rejects a do-nothing budget and a base config carrying dynamics.
    let none = PerturbBudget { max_outages: 0, max_link_events: 0, ..budget };
    assert!(search(
        &s.topo,
        &s.plan,
        &s.app,
        &base,
        &s.inputs,
        &[],
        &SearchConfig { budget: none, ..cfg }
    )
    .is_err());
    let with_dyn = base.with_dynamics(ScenarioTrace::empty("x"));
    assert!(search(&s.topo, &s.plan, &s.app, &with_dyn, &s.inputs, &[], &cfg).is_err());
}
