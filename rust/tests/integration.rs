//! Cross-module integration tests: optimizer → model → engine → runtime.

use mrperf::apps::SyntheticApp;
use mrperf::engine::job::JobConfig;
use mrperf::engine::run_job;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{evaluate, makespan, AppModel};
use mrperf::model::plan::Plan;
use mrperf::model::smooth::{selectors, smooth_makespan_plan};
use mrperf::optimizer::{AlternatingLp, Myopic, PlanOptimizer, Uniform};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::qcheck::{ensure, qcheck, Config};
use mrperf::util::rng::Pcg64;

/// Optimized plans must help (or at least not hurt) in the *engine*,
/// not just under the model — the end-to-end claim of the paper.
#[test]
fn optimized_plan_beats_uniform_in_engine() {
    let topo = build_env(EnvKind::Global8);
    for &alpha in &[0.1, 2.0] {
        let app_model = AppModel::new(alpha);
        let cfg = BarrierConfig::HADOOP;
        let plan = AlternatingLp::default().optimize(&topo, app_model, cfg);
        let uniform = Plan::uniform(8, 8, 8);
        let app = SyntheticApp::new(alpha);
        let inputs = synthetic_inputs(8, 1 << 20, 0x1A7E);
        let jc = JobConfig::default();
        let m_opt = run_job(&topo, &plan, &app, &jc, &inputs).metrics;
        let m_uni = run_job(&topo, &uniform, &app, &jc, &inputs).metrics;
        assert!(
            m_opt.makespan < m_uni.makespan,
            "α={alpha}: optimized {} should beat uniform {} in the engine",
            m_opt.makespan,
            m_uni.makespan
        );
    }
}

/// The model must *rank* plans the same way the engine does — ranking
/// fidelity is what makes model-driven optimization legitimate.
#[test]
fn model_ranks_plans_like_engine() {
    let topo = build_env(EnvKind::Global8);
    let alpha = 1.0;
    let app_model = AppModel::new(alpha);
    let cfg = BarrierConfig::HADOOP;
    let candidates = vec![
        ("uniform", Uniform.optimize(&topo, app_model, cfg)),
        ("myopic", Myopic.optimize(&topo, app_model, cfg)),
        ("e2e", AlternatingLp::default().optimize(&topo, app_model, cfg)),
        ("local-push", Plan::local_push(&topo)),
    ];
    let app = SyntheticApp::new(alpha);
    let inputs = synthetic_inputs(8, 1 << 20, 0xBEEF);
    let jc = JobConfig::default();
    let mut rows = Vec::new();
    for (name, plan) in &candidates {
        let pred = makespan(&topo, app_model, cfg, plan);
        let meas = run_job(&topo, plan, &app, &jc, &inputs).metrics.makespan;
        rows.push((*name, pred, meas));
    }
    // Kendall-τ-like check: no *strong* inversions. Pairs are skipped
    // when either side is within 15% — at the engine's scaled-down data
    // volume two near-optimal plans (e.g. myopic vs e2e) can measure as
    // a tie even when the model separates them (split granularity and
    // slot effects dominate below a handful of splits per node).
    for i in 0..rows.len() {
        for j in (i + 1)..rows.len() {
            let (na, pa, ma) = rows[i];
            let (nb, pb, mb) = rows[j];
            if (pa - pb).abs() / pa.max(pb) < 0.15 || (ma - mb).abs() / ma.max(mb) < 0.15 {
                continue;
            }
            assert_eq!(
                pa < pb,
                ma < mb,
                "rank inversion between {na} (pred {pa}, meas {ma}) and {nb} (pred {pb}, meas {mb})"
            );
        }
    }
}

/// Model↔engine conformance beyond `Global8` (ISSUE 1): on generated
/// hierarchical-WAN / federated / edge-heavy topologies, the model must
/// rank {uniform, myopic, e2e} plans the same way the engine measures
/// them. Pairs where either side is within 25% are skipped — at these
/// scaled-down data volumes near-optimal plans can measure as ties while
/// the engine adds contention the model ignores.
#[test]
fn model_ranks_plans_like_engine_on_generated_topologies() {
    use mrperf::platform::scale::{generate_kind, ScaleKind};
    let alpha = 1.0;
    let app_model = AppModel::new(alpha);
    let cfg = BarrierConfig::HADOOP;
    for kind in ScaleKind::all() {
        let topo = generate_kind(kind, 18, 0xA11CE);
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let candidates = vec![
            ("uniform", Plan::uniform(s, m, r)),
            ("myopic", Myopic.optimize(&topo, app_model, cfg)),
            (
                "e2e",
                AlternatingLp { random_starts: 1, ..Default::default() }
                    .optimize(&topo, app_model, cfg),
            ),
        ];
        let app = SyntheticApp::new(alpha);
        let inputs = synthetic_inputs(s, 1 << 18, 0xC0DE);
        let jc = JobConfig::default();
        let mut rows = Vec::new();
        for (name, plan) in &candidates {
            plan.check(&topo).unwrap_or_else(|e| panic!("{kind:?}/{name}: {e}"));
            let pred = makespan(&topo, app_model, cfg, plan);
            let meas = run_job(&topo, plan, &app, &jc, &inputs).metrics.makespan;
            rows.push((*name, pred, meas));
        }
        for i in 0..rows.len() {
            for j in (i + 1)..rows.len() {
                let (na, pa, ma) = rows[i];
                let (nb, pb, mb) = rows[j];
                if (pa - pb).abs() / pa.max(pb) < 0.25 || (ma - mb).abs() / ma.max(mb) < 0.25 {
                    continue;
                }
                assert_eq!(
                    pa < pb,
                    ma < mb,
                    "{kind:?}: rank inversion between {na} (pred {pa}, meas {ma}) and {nb} (pred {pb}, meas {mb})"
                );
            }
        }
    }
}

/// Property: makespan is monotone — more bandwidth or compute anywhere
/// never makes a fixed plan slower.
#[test]
fn makespan_monotone_in_resources() {
    qcheck(Config::default().cases(40), "resource monotonicity", |rng: &mut Pcg64| {
        let topo = build_env(EnvKind::Global4);
        let plan = Plan::random(8, 8, 8, rng);
        let app = AppModel::new(rng.uniform(0.1, 5.0));
        let cfg = BarrierConfig::ALL_GLOBAL;
        let base = makespan(&topo, app, cfg, &plan);

        let mut faster = topo.clone();
        // Scale up one random resource class.
        match rng.range(0, 3) {
            0 => {
                let i = rng.range(0, faster.b_sm.data().len());
                faster.b_sm.data_mut()[i] *= rng.uniform(1.0, 10.0);
            }
            1 => {
                let i = rng.range(0, faster.b_mr.data().len());
                faster.b_mr.data_mut()[i] *= rng.uniform(1.0, 10.0);
            }
            _ => {
                let i = rng.range(0, faster.c_map.len());
                faster.c_map[i] *= rng.uniform(1.0, 10.0);
                let k = rng.range(0, faster.c_red.len());
                faster.c_red[k] *= rng.uniform(1.0, 10.0);
            }
        }
        let improved = makespan(&faster, app, cfg, &plan);
        ensure(
            improved <= base + 1e-9,
            format!("faster resources made it slower: {base} -> {improved}"),
        )
    });
}

/// Property: every optimizer returns valid plans on random environments.
#[test]
fn optimizers_always_return_valid_plans() {
    qcheck(Config::default().cases(15), "optimizer validity", |rng: &mut Pcg64| {
        let kind = *rng.choose(&EnvKind::all());
        let topo = build_env(kind);
        let app = AppModel::new(rng.uniform(0.05, 8.0));
        let cfg = *rng.choose(&[
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ]);
        for plan in [
            Uniform.optimize(&topo, app, cfg),
            Myopic.optimize(&topo, app, cfg),
            AlternatingLp { random_starts: 1, ..Default::default() }.optimize(&topo, app, cfg),
        ] {
            if let Err(e) = plan.check(&topo) {
                return Err(format!("{kind:?} α={} cfg={}: {e}", app.alpha, cfg.label()));
            }
        }
        Ok(())
    });
}

/// Rust smooth model ↔ AOT artifact parity (the L2 contract), checked
/// through the plan_eval artifact when available.
#[test]
fn rust_smooth_model_matches_artifact_numerics() {
    let Ok(planner) = mrperf::runtime::ArtifactPlanner::load(2, 2, 2) else {
        return; // artifacts not built; covered by Makefile flow
    };
    let _ = planner; // loading itself exercises HLO parse + compile
    // Full numeric parity is asserted by runtime::client tests (the
    // §1.3 closed-form vector) and python tests (kernel vs ref).

    // Here: rust smooth upper-bounds rust exact on random plans, with
    // selector encoding consistent with the artifact convention.
    let topo = mrperf::platform::topology::example_1_3(100.0e6, 10.0e6, 100.0e6);
    let app = AppModel::new(1.0);
    let mut rng = Pcg64::new(5);
    for cfg in [BarrierConfig::ALL_GLOBAL, BarrierConfig::HADOOP] {
        let sels = selectors(cfg);
        assert_eq!(sels.len(), 6);
        for _ in 0..20 {
            let plan = Plan::random(2, 2, 2, &mut rng);
            let hard = makespan(&topo, app, cfg, &plan);
            let soft = smooth_makespan_plan(&topo, app, cfg, &plan, 400.0 / hard);
            assert!(soft >= hard - 1e-9);
            assert!((soft - hard) / hard < 0.05);
        }
    }
}

/// Barrier semantics: engine makespans respect the same ordering the
/// model predicts (pipelined ≤ global) across apps.
#[test]
fn engine_barrier_ordering_matches_model() {
    use mrperf::model::barrier::Barrier;
    let topo = build_env(EnvKind::Global4);
    let app = SyntheticApp::new(1.0);
    let inputs = synthetic_inputs(8, 1 << 19, 0xBA44);
    let plan = Plan::uniform(8, 8, 8);
    let mk = |pm, ms| JobConfig {
        barriers: BarrierConfig::new(pm, ms, Barrier::Local),
        ..Default::default()
    };
    let g = run_job(&topo, &plan, &app, &mk(Barrier::Global, Barrier::Global), &inputs)
        .metrics
        .makespan;
    let p = run_job(&topo, &plan, &app, &mk(Barrier::Pipelined, Barrier::Pipelined), &inputs)
        .metrics
        .makespan;
    assert!(p <= g * 1.001, "pipelined {p} should not exceed global {g}");
}

/// Timeline internals are consistent on every environment/barrier combo.
#[test]
fn timeline_internal_consistency() {
    let mut rng = Pcg64::new(77);
    for kind in EnvKind::all() {
        let topo = build_env(kind);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let plan = Plan::random(8, 8, 8, &mut rng);
            let tl = evaluate(&topo, AppModel::new(1.5), cfg, &plan);
            let max = |v: &[f64]| v.iter().cloned().fold(0.0, f64::max);
            assert!(max(&tl.map_end) >= max(&tl.push_end) - 1e-9 || cfg.push_map == mrperf::model::barrier::Barrier::Pipelined);
            assert!(tl.makespan >= max(&tl.shuffle_end) - 1e-9 || cfg.shuffle_reduce == mrperf::model::barrier::Barrier::Pipelined);
            assert!(tl.makespan > 0.0);
        }
    }
}
