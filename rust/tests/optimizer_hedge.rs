//! Failure-aware (hedged) optimizer acceptance tests (ISSUE 4):
//!
//! * `--hedge 0` is *bit-identical* to the unhedged alternating optimizer
//!   on all four paper environments — hedging is strictly opt-in;
//! * under a pinned reducer-failure trace on a generated 64-node
//!   platform, the hedged plan strictly beats the unhedged plan when both
//!   are executed with strict plan-local enforcement (the acceptance
//!   scenario of `mrperf experiment churn --profiles all --hedge`);
//! * the LPs built from a failure-discounted platform still pass the
//!   revised-vs-dense solver oracle.

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynEvent, ScenarioTrace, TimedEvent};
use mrperf::engine::job::{batch_size, JobConfig};
use mrperf::engine::run_job;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::AppModel;
use mrperf::model::plan::Plan;
use mrperf::optimizer::hedged::discount_topology;
use mrperf::optimizer::lp_build::{build_lp_x, build_lp_y, Objective};
use mrperf::optimizer::{AlternatingLp, FailureAwareOptimizer, PlanOptimizer};
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::{build_env, EnvKind};
use mrperf::solver::lp::Lp;

/// `--hedge 0` must reproduce the unhedged plan bit-for-bit on every
/// paper environment, across barrier configurations and α regimes.
#[test]
fn hedge_zero_is_bit_identical_on_all_paper_envs() {
    for kind in EnvKind::all() {
        let t = build_env(kind);
        for cfg in [BarrierConfig::ALL_GLOBAL, BarrierConfig::HADOOP] {
            for &alpha in &[0.1, 1.0, 10.0] {
                let app = AppModel::new(alpha);
                let hedged = FailureAwareOptimizer::new(0.0).optimize(&t, app, cfg);
                let plain = AlternatingLp::default().optimize(&t, app, cfg);
                assert_eq!(
                    hedged, plain,
                    "{kind:?}/{}/α={alpha}: --hedge 0 diverged from the unhedged plan",
                    cfg.label()
                );
            }
        }
    }
}

/// The acceptance scenario: on `hier-wan:64`, take down exactly the
/// reducers the hedge moved key-range mass *away from* (the unhedged
/// plan's concentration points), from t=0 until well past both static
/// makespans. Both plans run under strict plan-local enforcement — no
/// runtime adaptivity — so the comparison isolates failure-aware
/// planning: the unhedged plan strands strictly more key-range mass on
/// the dead reducers and pays a strictly longer replay/reduce tail.
#[test]
fn hedged_plan_beats_unhedged_under_pinned_failure_trace_at_64_nodes() {
    let gen = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
    let inputs = synthetic_inputs(gen.n_sources(), 1 << 13, 0x5CA1E);
    let mean_bytes =
        inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / gen.n_sources() as f64;
    let topo = gen.with_uniform_data(mean_bytes);
    let app = AppModel::new(1.0);
    let cfg = BarrierConfig::HADOOP;
    let rate = 0.3;

    let unhedged = AlternatingLp::default().optimize(&topo, app, cfg);
    let hedged = FailureAwareOptimizer::new(rate).optimize(&topo, app, cfg);
    unhedged.check(&topo).unwrap();
    hedged.check(&topo).unwrap();

    // The reducers the hedge meaningfully de-concentrated (≥1% of the
    // key space, i.e. several partitioner buckets). If this set is empty
    // the hedge is not doing its job.
    let victims: Vec<usize> = (0..topo.n_reducers())
        .filter(|&k| unhedged.y[k] - hedged.y[k] > 0.01)
        .collect();
    assert!(
        !victims.is_empty(),
        "hedging must move key-range mass off the concentration points \
         (unhedged y = {:?}, hedged y = {:?})",
        unhedged.y,
        hedged.y
    );

    let sapp = SyntheticApp::new(1.0);
    let s_u = run_job(&topo, &unhedged, &sapp, &JobConfig::optimized(), &inputs)
        .metrics
        .makespan;
    let s_h =
        run_job(&topo, &hedged, &sapp, &JobConfig::optimized(), &inputs).metrics.makespan;
    let recover_at = 2.2 * s_u.max(s_h);

    let mut events = Vec::new();
    for &v in &victims {
        events.push(TimedEvent { time: 0.0, event: DynEvent::ReducerFail { node: v } });
        events.push(TimedEvent { time: recover_at, event: DynEvent::ReducerRecover { node: v } });
    }
    let trace = ScenarioTrace::from_events("pinned-reducer-outage", events);

    let m_u = run_job(
        &topo,
        &unhedged,
        &sapp,
        &JobConfig::optimized().with_dynamics(trace.clone()),
        &inputs,
    )
    .metrics;
    let m_h = run_job(
        &topo,
        &hedged,
        &sapp,
        &JobConfig::optimized().with_dynamics(trace),
        &inputs,
    )
    .metrics;

    for (label, m) in [("unhedged", &m_u), ("hedged", &m_h)] {
        assert_eq!(m.output_records, m.input_records, "{label} lost records");
        assert_eq!(m.shuffle_bytes_delivered, m.shuffle_bytes, "{label} lost bytes");
        assert_eq!(m.reducers_failed, victims.len(), "{label}");
    }
    // The unhedged plan concentrated on the victims, so it stalls for
    // the full outage; the hedge bounds the stranded mass.
    assert!(
        m_u.makespan > recover_at,
        "unhedged plan-local must stall past recovery ({} vs {recover_at})",
        m_u.makespan
    );
    assert!(
        m_h.makespan < m_u.makespan,
        "hedged plan ({}) must strictly beat the unhedged plan ({}) under the outage",
        m_h.makespan,
        m_u.makespan
    );
}

/// The hedged LPs are ordinary makespan LPs over a rescaled platform —
/// they must still satisfy the revised-vs-dense solver cross-check on
/// every paper-env shape (the tests/optimizer_scale.rs oracle, applied
/// to the discounted topology).
#[test]
fn hedged_lps_pass_the_solver_oracle() {
    fn assert_solvers_agree(lp: &Lp, ctx: &str) {
        let (xd, od) = mrperf::solver::solve_robust_dense(lp).expect_optimal(ctx);
        let (xs, os) = mrperf::solver::revised::solve(lp).expect_optimal(ctx);
        assert!(lp.violation(&xs) < 1e-6, "{ctx}: revised violation {}", lp.violation(&xs));
        assert!(lp.violation(&xd) < 1e-6, "{ctx}: dense violation {}", lp.violation(&xd));
        assert!(
            (od - os).abs() <= 1e-7 * od.abs().max(1.0),
            "{ctx}: dense objective {od} vs revised {os}"
        );
    }

    let app = AppModel::new(1.3);
    for kind in [EnvKind::Global4, EnvKind::Global8] {
        let t = discount_topology(&build_env(kind), 0.3);
        let r = t.n_reducers();
        for cfg in [BarrierConfig::ALL_GLOBAL, BarrierConfig::HADOOP] {
            let uniform = vec![1.0 / r as f64; r];
            let mut one_hot = vec![0.0; r];
            one_hot[0] = 1.0;
            for (yi, y) in [uniform, one_hot].iter().enumerate() {
                let (lp, _) = build_lp_x(&t, app, cfg, y, Objective::Makespan);
                assert_solvers_agree(
                    &lp,
                    &format!("hedged/{kind:?}/{}/lp_x[y{yi}]", cfg.label()),
                );
            }
            let x = Plan::local_push(&t).x;
            let (lp, _) = build_lp_y(&t, app, cfg, &x, Objective::Makespan);
            assert_solvers_agree(&lp, &format!("hedged/{kind:?}/{}/lp_y", cfg.label()));
        }
    }
}
