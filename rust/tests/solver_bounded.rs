//! Bounded-variable revised simplex vs the dense oracle (ISSUE 7).
//!
//! The sparse solver now keeps `0 ≤ x ≤ u` (and shifted lower bounds)
//! implicit; the dense tableau portfolio still materializes every bound
//! as an explicit row. Agreement between the two on every LP shape the
//! paper environments actually emit — x-step, y-step, hedged, and
//! symmetry-aggregated quotient programs, under every barrier config —
//! is the correctness gate for the bound handling, and a devex-vs-
//! Dantzig A/B on the same instances pins pricing down as a pure
//! speed/ordering choice that never changes the optimum.

use mrperf::model::barrier::{Barrier, BarrierConfig};
use mrperf::model::makespan::AppModel;
use mrperf::model::plan::Plan;
use mrperf::optimizer::aggregate::quotient;
use mrperf::optimizer::hedged::discount_topology;
use mrperf::optimizer::lp_build::{build_lp_x, build_lp_y, Objective};
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::{build_env, EnvKind, Topology};
use mrperf::solver::{revised, solve_robust_dense, Lp, LpOutcome, Pricing};

/// Barrier configs that exercise all three single-variable-row →
/// implicit-bound conversion sites in `lp_build` (the Pipelined branches)
/// as well as the unconverted shapes.
fn barrier_configs() -> Vec<BarrierConfig> {
    let all = [Barrier::Global, Barrier::Local, Barrier::Pipelined];
    let mut out = vec![BarrierConfig::HADOOP, BarrierConfig::ALL_GLOBAL];
    for b in all {
        out.push(BarrierConfig::new(b, Barrier::Pipelined, Barrier::Pipelined));
    }
    out
}

/// Every plan-LP shape a topology emits under a barrier config.
fn plan_lps(topo: &Topology, cfg: BarrierConfig) -> Vec<(String, Lp)> {
    let app = AppModel::new(1.0);
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let y0 = vec![1.0 / r as f64; r];
    let x0 = Plan::uniform(s, m, r).x;
    let mut out = Vec::new();
    for obj in [Objective::Makespan, Objective::PushTime, Objective::ShuffleEnd] {
        let (lpx, _) = build_lp_x(topo, app, cfg, &y0, obj);
        out.push((format!("{} x-LP {obj:?} {}", topo.name, cfg.label()), lpx));
    }
    let (lpy, _) = build_lp_y(topo, app, cfg, &x0, Objective::Makespan);
    out.push((format!("{} y-LP {}", topo.name, cfg.label()), lpy));
    out
}

fn optimal_objective(out: &LpOutcome, label: &str) -> f64 {
    match out {
        LpOutcome::Optimal { objective, .. } => *objective,
        other => panic!("{label}: expected Optimal, got {other:?}"),
    }
}

fn assert_close(a: f64, b: f64, label: &str) {
    let scale = 1.0 + a.abs().max(b.abs());
    assert!(
        (a - b).abs() <= 1e-7 * scale,
        "{label}: bounded revised {a} vs dense oracle {b} (rel diff {})",
        (a - b).abs() / scale
    );
}

/// Check the sparse bounded solver against the dense portfolio on one
/// LP, then check that both pricing rules land on the same optimum.
fn check_lp(label: &str, lp: &Lp) {
    let dense = optimal_objective(&solve_robust_dense(lp), label);
    let (devex_out, _) = revised::solve_warm_pricing(lp, None, Pricing::Devex);
    let devex = optimal_objective(
        &devex_out.unwrap_or_else(|| panic!("{label}: devex solve failed")),
        label,
    );
    assert_close(devex, dense, label);
    let (dantzig_out, _) = revised::solve_warm_pricing(lp, None, Pricing::Dantzig);
    let dantzig = optimal_objective(
        &dantzig_out.unwrap_or_else(|| panic!("{label}: dantzig solve failed")),
        label,
    );
    assert_close(dantzig, dense, &format!("{label} [dantzig]"));
}

/// Every paper environment × barrier config × objective: the bounded
/// revised simplex agrees with the dense oracle to 1e-7.
#[test]
fn bounded_matches_dense_on_every_paper_env_lp() {
    for env in EnvKind::all() {
        let topo = build_env(env);
        for cfg in barrier_configs() {
            for (label, lp) in plan_lps(&topo, cfg) {
                check_lp(&label, &lp);
            }
        }
    }
}

/// Hedged planning solves the same LP shapes on a capacity-discounted
/// topology; the bound handling must survive the discount too.
#[test]
fn bounded_matches_dense_on_hedged_lps() {
    for env in EnvKind::all() {
        let topo = discount_topology(&build_env(env), 0.1);
        for (label, lp) in plan_lps(&topo, BarrierConfig::HADOOP) {
            check_lp(&format!("hedged {label}"), &lp);
        }
    }
}

/// Symmetry-aggregated (quotient) instances of generated topologies:
/// this is the LP shape the alternating optimizer actually solves at
/// scale, with per-group weights far from 1.
#[test]
fn bounded_matches_dense_on_aggregated_quotient_lps() {
    for kind in
        [ScaleKind::HierarchicalWan, ScaleKind::FederatedDataCenters, ScaleKind::EdgeHeavy]
    {
        let topo = generate_kind(kind, 64, 7);
        let q = quotient(&topo).expect("64-node generated topologies aggregate");
        for cfg in [BarrierConfig::HADOOP, BarrierConfig::ALL_GLOBAL] {
            for (label, lp) in plan_lps(&q.topo, cfg) {
                check_lp(&format!("quotient {label}"), &lp);
            }
        }
    }
}
