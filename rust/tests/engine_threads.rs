//! Thread-count invariance (ISSUE 7): the parallel fluid re-solve is a
//! pure wall-clock optimization. Every metric of every run — static,
//! under every dynamics profile, and across a multi-tenant stream — must
//! be bit-identical for every `JobConfig::threads` value ≥ 1, because
//! the solver shards *whole dirty components* with a fixed assignment
//! (`component_index % threads`) and each component's fill is the same
//! sequential arithmetic wherever it runs. A run that differs by one ULP
//! under `--threads 8` is a bug, not noise.

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynProfile, ScenarioTrace, TraceShape};
use mrperf::engine::job::JobConfig;
use mrperf::engine::tenancy::{run_stream, StreamJob};
use mrperf::engine::{run_job, stream_policy, JobMetrics};
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::plan::Plan;
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::Topology;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Bit-exact signature over every metric field, including the fluid
/// hot-path counters (the incremental solver touches the same components
/// in the same order whatever the thread count, so even the counters
/// must match exactly).
fn sig(m: &JobMetrics) -> String {
    format!(
        "{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        m.makespan.to_bits(),
        m.push_end.to_bits(),
        m.map_end.to_bits(),
        m.shuffle_end.to_bits(),
        m.push_bytes.to_bits(),
        m.shuffle_bytes.to_bits(),
        m.output_bytes.to_bits(),
        m.reduce_bytes_replayed.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_repushed.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.spec_launched,
        m.spec_won,
        m.stolen,
        m.dyn_events,
        m.failures_injected,
        m.tasks_requeued,
        m.reducers_failed,
        m.reduce_ranges_reassigned,
        m.sources_refreshed,
        m.input_records,
        m.intermediate_records,
        m.output_records,
        m.fluid_resolves,
        m.fluid_resources_touched
    )
}

fn setup() -> (Topology, Plan) {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    (topo, plan)
}

fn config_with_threads(threads: usize) -> JobConfig {
    let mut c = JobConfig::default();
    c.threads = threads;
    c
}

/// Static run: one job, four thread counts, one signature.
#[test]
fn run_job_is_bit_identical_across_thread_counts() {
    let (topo, plan) = setup();
    let app = SyntheticApp::new(1.0);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);

    let baseline = run_job(&topo, &plan, &app, &config_with_threads(1), &inputs);
    let base_sig = sig(&baseline.metrics);
    assert!(baseline.metrics.fluid_resolves > 0, "probe must exercise the solver");
    for &t in &THREAD_COUNTS[1..] {
        let res = run_job(&topo, &plan, &app, &config_with_threads(t), &inputs);
        assert_eq!(
            base_sig,
            sig(&res.metrics),
            "threads={t} diverged from the single-thread run"
        );
        // Outputs too: the records the reducers emit must be untouched.
        assert_eq!(baseline.outputs, res.outputs, "threads={t} changed job output");
    }
}

/// Every dynamics profile (failures, stragglers, churn, staleness, …)
/// perturbs the event stream mid-run; the re-solve cascade after each
/// event must still be thread-count invariant.
#[test]
fn dynamics_runs_are_bit_identical_across_thread_counts() {
    let (topo, plan) = setup();
    let app = SyntheticApp::new(1.0);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    let horizon = run_job(&topo, &plan, &app, &config_with_threads(1), &inputs)
        .metrics
        .makespan;

    for profile in DynProfile::all() {
        let trace =
            ScenarioTrace::generate(profile, 7, &TraceShape::of(&topo, horizon));
        let run = |threads: usize| {
            let cfg = config_with_threads(threads).with_dynamics(trace.clone());
            sig(&run_job(&topo, &plan, &app, &cfg, &inputs).metrics)
        };
        let base = run(1);
        for &t in &THREAD_COUNTS[1..] {
            assert_eq!(
                base,
                run(t),
                "threads={t} diverged under the {} profile",
                profile.label()
            );
        }
    }
}

/// A multi-tenant fair-share stream shares ONE simulator across jobs
/// (the stream solves with the widest per-job thread request): per-job
/// metrics, outcome times, and the stream makespan must all match the
/// single-thread stream bit for bit — including when jobs *disagree*
/// about the thread count.
#[test]
fn tenancy_stream_is_bit_identical_across_thread_counts() {
    let (topo, plan) = setup();
    let app = SyntheticApp::new(1.0);
    let inputs_a = synthetic_inputs(topo.n_sources(), 1 << 13, 0xA11CE);
    let inputs_b = synthetic_inputs(topo.n_sources(), 1 << 13, 0xB0B);
    let arr2 = 0.25
        * run_job(&topo, &plan, &app, &config_with_threads(1), &inputs_a)
            .metrics
            .makespan;

    let run = |thread_triple: [usize; 3]| {
        let cfgs: Vec<JobConfig> =
            thread_triple.iter().map(|&t| config_with_threads(t)).collect();
        let jobs = vec![
            StreamJob::new(0.0, &plan, &app, &cfgs[0], &inputs_a),
            StreamJob::new(0.0, &plan, &app, &cfgs[1], &inputs_b),
            StreamJob::new(arr2, &plan, &app, &cfgs[2], &inputs_a),
        ];
        let mut policy = stream_policy("fair-share").unwrap();
        let res = run_stream(&topo, &jobs, policy.as_mut(), None).unwrap();
        let mut out = vec![format!("{:x}", res.makespan.to_bits())];
        for o in &res.jobs {
            out.push(format!(
                "{:x}/{:x}/{}",
                o.started.to_bits(),
                o.finished.to_bits(),
                sig(o.metrics.as_ref().expect("stream job must complete"))
            ));
        }
        out
    };

    let base = run([1, 1, 1]);
    for triple in [[2, 2, 2], [4, 4, 4], [8, 8, 8], [1, 4, 2]] {
        assert_eq!(base, run(triple), "stream diverged with threads {triple:?}");
    }
}
