//! Checkpoint/resume + dead-letter-queue properties (ISSUE 9):
//!
//! * **resume bit-identity** — `resume(checkpoint(t))` finishes
//!   bit-identical to the uninterrupted run, for every dynamics profile
//!   and multiple checkpoint/crash times, via the crash-simulating
//!   driver (`run_job_with_recovery`);
//! * **zero-flag neutrality** — the recovery driver with recovery off
//!   reproduces `run_job` bit for bit;
//! * **bounded retries** — a flapping trace that evicts the same work
//!   over and over dead-letters it at the retry budget instead of
//!   requeueing forever (the pre-DLQ engine livelocked here), for both
//!   scheduler families;
//! * **exhausted ranges reach the DLQ** — an all-reducer blackout with
//!   budget 1 ends `PartialWithDlq` with every undelivered shuffle byte
//!   accounted in the dead-letter queue
//!   (`shuffle_bytes_delivered + dlq_bytes == shuffle_bytes`, exact).

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynEvent, DynProfile, ScenarioTrace, TimedEvent, TraceShape};
use mrperf::engine::executor::JobOutcome;
use mrperf::engine::job::{batch_size, JobConfig};
use mrperf::engine::{
    run_job, run_job_with_recovery, DlqKind, JobMetrics, RecoveryOpts, ReplanPolicy,
};
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::AppModel;
use mrperf::model::plan::Plan;
use mrperf::optimizer::{AlternatingLp, PlanOptimizer};
use mrperf::platform::scale::{generate_kind, ScaleKind};

/// Bit-exact signature of every metric field (floats by bit pattern).
/// `coordinator_restarts` and `replans_skipped` are deliberately
/// excluded: both are provenance (crashes survived, re-solve
/// evaluations declined — a resume re-evaluates one boundary), and the
/// checkpoint/resume invariant is exactly that everything else matches
/// bit for bit. Accepted replans and the migration counters ARE part of
/// the identity: a resumed replanning run must replay them exactly.
fn sig(m: &JobMetrics) -> String {
    format!(
        "{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        m.makespan.to_bits(),
        m.push_end.to_bits(),
        m.map_end.to_bits(),
        m.shuffle_end.to_bits(),
        m.push_bytes.to_bits(),
        m.shuffle_bytes.to_bits(),
        m.output_bytes.to_bits(),
        m.reduce_bytes_replayed.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_repushed.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.dlq_bytes.to_bits(),
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.spec_launched,
        m.spec_won,
        m.stolen,
        m.dyn_events,
        m.failures_injected,
        m.tasks_requeued,
        m.reducers_failed,
        m.reduce_ranges_reassigned,
        m.sources_refreshed,
        m.splits_dead_lettered,
        m.ranges_dead_lettered,
        m.input_records,
        m.intermediate_records,
        m.output_records,
        m.replans,
        m.replan_migrated_splits,
        m.replan_migrated_ranges
    )
}

/// With no recovery flag set the driver is `run_job`, bit for bit.
#[test]
fn recovery_driver_with_recovery_off_is_bit_identical_to_run_job() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    let app = SyntheticApp::new(1.0);
    for cfg in [JobConfig::default(), JobConfig::dynamic_locality()] {
        let plain = run_job(&topo, &plan, &app, &cfg, &inputs);
        let recov = run_job_with_recovery(
            &topo,
            &plan,
            &app,
            &cfg,
            &inputs,
            &RecoveryOpts::default(),
        )
        .unwrap();
        assert_eq!(sig(&plain.metrics), sig(&recov.metrics));
        assert_eq!(recov.metrics.coordinator_restarts, 0);
        assert_eq!(plain.outputs, recov.outputs);
    }
}

/// The tentpole invariant, swept: for EVERY dynamics profile and two
/// distinct crash times, a run that checkpoints, crashes and resumes
/// finishes bit-identical to the uninterrupted run — same metrics
/// (restart counter aside), same outputs.
#[test]
fn crashed_run_resumes_bit_identical_for_every_profile() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    let app = SyntheticApp::new(1.0);

    // One static run fixes the trace horizon for every profile.
    let stat = run_job(&topo, &plan, &app, &JobConfig::default(), &inputs).metrics;

    // No-dynamics case plus every profile; plan-local everywhere, and
    // the dynamic scheduler additionally on the richest-state profiles
    // (speculation/stealing/reassignment state must round-trip too).
    let mut cases: Vec<(Option<DynProfile>, JobConfig)> =
        vec![(None, JobConfig::default())];
    for p in DynProfile::all() {
        cases.push((Some(p), JobConfig::default()));
    }
    for p in [DynProfile::Churn, DynProfile::Staleness] {
        cases.push((Some(p), JobConfig::dynamic_locality()));
    }

    for (profile, base) in cases {
        let cfg = match profile {
            Some(p) => base.clone().with_dynamics(ScenarioTrace::generate(
                p,
                7,
                &TraceShape::of(&topo, stat.makespan),
            )),
            None => base.clone(),
        };
        let reference = run_job(&topo, &plan, &app, &cfg, &inputs);
        for crash_frac in [0.3, 0.7] {
            let opts = RecoveryOpts {
                checkpoint_every: Some(reference.metrics.makespan / 10.0),
                crash_at: Some(reference.metrics.makespan * crash_frac),
                ..RecoveryOpts::default()
            };
            let resumed =
                run_job_with_recovery(&topo, &plan, &app, &cfg, &inputs, &opts).unwrap();
            assert_eq!(
                sig(&reference.metrics),
                sig(&resumed.metrics),
                "{profile:?} crash at {crash_frac}: resumed run diverged"
            );
            assert_eq!(
                resumed.metrics.coordinator_restarts, 1,
                "{profile:?} crash at {crash_frac}: exactly one restart"
            );
            assert_eq!(
                reference.outputs, resumed.outputs,
                "{profile:?} crash at {crash_frac}: outputs diverged"
            );
        }
    }
}

/// A synchronized flapping trace — every mapper failing and recovering
/// on a cycle shorter than one map task's compute time — used to
/// livelock the engine: each eviction requeued the task unconditionally
/// and the run never terminated. With the retry budget, every split is
/// dead-lettered after exactly `max_attempts` evictions: the run ends
/// `PartialWithDlq`, requeues are bounded by `splits × (budget − 1)`,
/// and the byte ledger still reconciles exactly. Both scheduler
/// families (stealing has no live target during the synchronized
/// outages, so it exhausts the same budget).
#[test]
fn flapping_trace_dead_letters_instead_of_livelocking() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xF1A9);
    // Compute-bound maps: one task needs the whole inter-failure window
    // many times over, so it can never finish between flaps.
    let app = SyntheticApp::new(1.0).with_costs(50.0, 1.0);
    let budget = 2u32;

    let stat = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;
    let d = stat.map_end - stat.push_end;
    assert!(d > 0.0, "map phase must be non-trivial");
    // 6 fail/recover cycles of period d/8 starting inside the map
    // phase: each up-window is d/16 — far shorter than a task.
    let p = d / 8.0;
    let mut events = Vec::new();
    for c in 0..6 {
        let fail = stat.push_end + (c as f64 + 0.5) * p;
        let recover = stat.push_end + (c as f64 + 1.0) * p;
        for j in 0..topo.n_mappers() {
            events.push(TimedEvent { time: fail, event: DynEvent::MapperFail { node: j } });
            events.push(TimedEvent {
                time: recover,
                event: DynEvent::MapperRecover { node: j },
            });
        }
    }
    let trace = ScenarioTrace::from_events("flapping", events);

    for (plan_local, base) in
        [(true, JobConfig::optimized()), (false, JobConfig::dynamic_locality())]
    {
        let cfg = JobConfig { max_attempts: budget, ..base.clone() }
            .with_dynamics(trace.clone());
        // Pre-fix this call never returned (unbounded requeue loop).
        let res = run_job(&topo, &plan, &app, &cfg, &inputs);
        let m = &res.metrics;
        assert!(
            matches!(res.outcome, JobOutcome::PartialWithDlq),
            "plan_local={plan_local}: flapped-to-death work must end partial"
        );
        assert!(!res.dlq.is_empty(), "plan_local={plan_local}: DLQ must be non-empty");
        assert!(
            m.splits_dead_lettered > 0,
            "plan_local={plan_local}: splits must be dead-lettered"
        );
        assert_eq!(
            res.dlq.of_kind(DlqKind::Split).count(),
            m.splits_dead_lettered,
            "plan_local={plan_local}: DLQ entries must match the counter"
        );
        // Every attempt is budgeted: a split is requeued at most
        // budget − 1 times before its next eviction dead-letters it.
        assert!(
            m.tasks_requeued <= m.n_map_tasks * (budget as usize - 1),
            "plan_local={plan_local}: requeues {} exceed the budget bound \
             ({} splits, budget {budget})",
            m.tasks_requeued,
            m.n_map_tasks
        );
        // Dead splits never emitted shuffle data, so what WAS emitted
        // still reconciles exactly.
        assert_eq!(
            (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits(),
            m.shuffle_bytes.to_bits(),
            "plan_local={plan_local}: byte ledger must reconcile"
        );
        if plan_local {
            // Pinned tasks cannot escape the flapping: every split dies.
            assert_eq!(m.splits_dead_lettered, m.n_map_tasks);
            assert_eq!(m.output_records, 0, "no split survived to produce output");
        }
    }
}

/// All-reducer blackout with retry budget 1 and NO recovery: every
/// range whose reduce had not completed is dead-lettered at failure
/// time — even though no reassignment target exists — and the job ends
/// `PartialWithDlq` with every undelivered shuffle byte in the DLQ.
/// (Pre-fix, a range that counted a failed attempt while no live
/// adopter existed was simply parked forever; with no recovery event
/// the run never terminated.)
#[test]
fn reducer_blackout_with_budget_one_dead_letters_every_unfinished_range() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0x10AD);
    // Slow reduce: the failure lands while reduce compute is in flight.
    let app = SyntheticApp::new(1.0).with_costs(1.0, 50.0);

    let stat = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;
    assert!(stat.makespan > stat.shuffle_end, "reduce phase must be non-trivial");
    let fail_at = 0.5 * (stat.shuffle_end + stat.makespan);
    let events: Vec<TimedEvent> = (0..topo.n_reducers())
        .map(|k| TimedEvent { time: fail_at, event: DynEvent::ReducerFail { node: k } })
        .collect();
    let trace = ScenarioTrace::from_events("blackout-no-recovery", events);

    for (plan_local, base) in
        [(true, JobConfig::optimized()), (false, JobConfig::dynamic_locality())]
    {
        let cfg =
            JobConfig { max_attempts: 1, ..base.clone() }.with_dynamics(trace.clone());
        let res = run_job(&topo, &plan, &app, &cfg, &inputs);
        let m = &res.metrics;
        assert_eq!(m.reducers_failed, topo.n_reducers(), "plan_local={plan_local}");
        assert!(
            matches!(res.outcome, JobOutcome::PartialWithDlq),
            "plan_local={plan_local}: a permanent blackout must end partial"
        );
        assert!(
            m.ranges_dead_lettered > 0,
            "plan_local={plan_local}: unfinished ranges must be dead-lettered"
        );
        assert_eq!(
            res.dlq.of_kind(DlqKind::Range).count(),
            m.ranges_dead_lettered,
            "plan_local={plan_local}: DLQ entries must match the counter"
        );
        assert!(m.dlq_bytes > 0.0, "plan_local={plan_local}: lost bytes must be accounted");
        // THE reconciliation identity: every shuffle byte is either
        // delivered to a completed range or dead-lettered — exactly.
        assert_eq!(
            (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits(),
            m.shuffle_bytes.to_bits(),
            "plan_local={plan_local}: delivered {} + dlq {} != shuffled {}",
            m.shuffle_bytes_delivered,
            m.dlq_bytes,
            m.shuffle_bytes
        );
        // Records from dead ranges never reach the output.
        assert!(
            m.output_records < m.input_records,
            "plan_local={plan_local}: dead ranges cannot produce their records"
        );
    }
}

/// The retry budget's zero value is rejected loudly, not treated as
/// "unbounded" (the pre-fix behavior the budget exists to remove).
#[test]
#[should_panic(expected = "max_attempts must be >= 1")]
fn zero_retry_budget_is_rejected() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 10, 1);
    let cfg = JobConfig { max_attempts: 0, ..JobConfig::default() };
    let _ = run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs);
}

/// Replanning composes with checkpoint/resume (the ISSUE 10
/// composition invariant): a coordinator crash *between two accepted
/// replan events* resumes bit-identical — same accepted re-solves, same
/// migrations, same outputs — because the warm-start bases, the
/// baseline platform fingerprint and the current shuffle split all
/// round-trip through the snapshot.
#[test]
fn crash_between_two_replan_events_resumes_bit_identical() {
    let gen = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let inputs = synthetic_inputs(gen.n_sources(), 1 << 13, 0xD11A);
    let app = SyntheticApp::new(1.0);
    // Price the model on the simulated volume (the fig4 idiom) so the
    // initial plan is near-optimal: replanning then cannot outrun the
    // static horizon and both trace events land mid-run.
    let mean =
        inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / gen.n_sources() as f64;
    let topo = gen.with_uniform_data(mean);
    let plan = AlternatingLp::default().optimize(&topo, AppModel::new(1.0), BarrierConfig::HADOOP);
    let h = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics.makespan;

    // A 10x WAN cut on the busiest reducer's cluster, then a full
    // restore (`ClusterLinkScale` factors are absolute, so 1.0
    // restores): each swings the effective-platform fingerprint far
    // past the hysteresis band, so an on-event replanner re-solves at
    // both boundaries.
    let best = (0..topo.n_reducers()).max_by(|&a, &b| plan.y[a].total_cmp(&plan.y[b])).unwrap();
    let cluster = topo.reducer_cluster[best];
    let trace = ScenarioTrace::from_events(
        "cut-then-restore",
        vec![
            TimedEvent {
                time: h * 0.2,
                event: DynEvent::ClusterLinkScale { cluster, factor: 0.1 },
            },
            TimedEvent {
                time: h * 0.55,
                event: DynEvent::ClusterLinkScale { cluster, factor: 1.0 },
            },
        ],
    );
    let cfg = JobConfig::optimized()
        .with_dynamics(trace)
        .with_replan(ReplanPolicy::OnEvent, 1.0);
    let reference = run_job(&topo, &plan, &app, &cfg, &inputs);
    assert_eq!(
        reference.metrics.replans, 2,
        "both trace boundaries must accept a re-solve: {:?}",
        reference.metrics
    );

    // Crash strictly between the two replan events; the resumed run
    // must replay the second re-solve from the snapshot's warm bases.
    let opts = RecoveryOpts {
        checkpoint_every: Some(h * 0.08),
        crash_at: Some(h * 0.35),
        ..RecoveryOpts::default()
    };
    let resumed = run_job_with_recovery(&topo, &plan, &app, &cfg, &inputs, &opts).unwrap();
    assert_eq!(
        sig(&reference.metrics),
        sig(&resumed.metrics),
        "resumed replanning run diverged from the uninterrupted one"
    );
    assert_eq!(resumed.metrics.replans, 2);
    assert_eq!(resumed.metrics.coordinator_restarts, 1);
    assert_eq!(reference.outputs, resumed.outputs, "outputs diverged across the crash");
}

/// A snapshot records the replan policy in its compat header: resuming
/// under any *different* policy is refused loudly (the resumed run
/// would otherwise silently re-solve on a different cadence), while the
/// same policy resumes bit-identically — the resume-time boundary
/// re-evaluation lands only in the sig-excluded `replans_skipped`
/// provenance counter.
#[test]
fn snapshot_refuses_resume_under_a_different_replan_policy() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    let app = SyntheticApp::new(1.0);
    let cfg_on = JobConfig::optimized().with_replan(ReplanPolicy::OnEvent, 1.0);
    let base = run_job(&topo, &plan, &app, &cfg_on, &inputs);

    let dir = std::env::temp_dir().join("mrperf-replan-compat-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.json");
    let opts = RecoveryOpts {
        checkpoint_every: Some(base.metrics.makespan * 0.4),
        checkpoint_path: Some(path.to_string_lossy().into_owned()),
        ..RecoveryOpts::default()
    };
    run_job_with_recovery(&topo, &plan, &app, &cfg_on, &inputs, &opts).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();

    for other in [
        JobConfig::optimized(),
        JobConfig::optimized().with_replan(ReplanPolicy::Every(2.0), 1.0),
    ] {
        let err = run_job_with_recovery(
            &topo,
            &plan,
            &app,
            &other,
            &inputs,
            &RecoveryOpts { resume_from: Some(text.clone()), ..RecoveryOpts::default() },
        )
        .unwrap_err();
        assert!(
            err.contains("incompatible") && err.contains("replan"),
            "wrong rejection message: {err}"
        );
    }

    // The matching policy resumes and finishes bit-identically; the
    // resume re-evaluates one boundary, which must decline (nothing
    // about the platform changed).
    let resumed = run_job_with_recovery(
        &topo,
        &plan,
        &app,
        &cfg_on,
        &inputs,
        &RecoveryOpts { resume_from: Some(text), ..RecoveryOpts::default() },
    )
    .unwrap();
    assert_eq!(sig(&base.metrics), sig(&resumed.metrics));
    assert!(
        resumed.metrics.replans_skipped >= 1,
        "the resume must have re-evaluated (and declined) the boundary"
    );
}
