//! Dynamics-layer properties (ISSUE 3):
//!
//! * **determinism** — identical `(trace seed, platform seed)` pairs give
//!   bit-identical metrics with dynamics enabled;
//! * **zero-event neutrality** — a trace with no events reproduces the
//!   static engine's metrics bit-for-bit (the dynamics plumbing must not
//!   perturb the arithmetic);
//! * **no lost work** — tasks on failed nodes always complete somewhere
//!   (re-queued to the recovered node under plan-local enforcement,
//!   stolen elsewhere under the dynamic policy), with full record
//!   conservation;
//! * **recovery beats enforcement** — under a failure trace the
//!   locality-aware dynamic scheduler strictly beats plan-local
//!   enforcement on makespan.

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynEvent, DynProfile, ScenarioTrace, TimedEvent, TraceShape};
use mrperf::engine::job::JobConfig;
use mrperf::engine::{run_job, JobMetrics};
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::plan::Plan;
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::qcheck::{ensure, qcheck, Config};

/// Bit-exact signature of every metric field (floats by bit pattern).
/// `coordinator_restarts` and `replans_skipped` are deliberately
/// excluded: both are provenance (crashes survived, re-solve
/// evaluations declined — a resume re-evaluates one boundary), and the
/// checkpoint/resume invariant is exactly that everything else matches
/// bit for bit. Accepted replans and the migration counters ARE part of
/// the identity: a resumed replanning run must replay them exactly.
fn sig(m: &JobMetrics) -> String {
    format!(
        "{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        m.makespan.to_bits(),
        m.push_end.to_bits(),
        m.map_end.to_bits(),
        m.shuffle_end.to_bits(),
        m.push_bytes.to_bits(),
        m.shuffle_bytes.to_bits(),
        m.output_bytes.to_bits(),
        m.reduce_bytes_replayed.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_repushed.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.dlq_bytes.to_bits(),
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.spec_launched,
        m.spec_won,
        m.stolen,
        m.dyn_events,
        m.failures_injected,
        m.tasks_requeued,
        m.reducers_failed,
        m.reduce_ranges_reassigned,
        m.sources_refreshed,
        m.splits_dead_lettered,
        m.ranges_dead_lettered,
        m.input_records,
        m.intermediate_records,
        m.output_records,
        m.replans,
        m.replan_migrated_splits,
        m.replan_migrated_ranges
    )
}

fn small_job(
    kind: ScaleKind,
    nodes: usize,
    seed: u64,
    cfg: &JobConfig,
) -> JobMetrics {
    let topo = generate_kind(kind, nodes, seed);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    run_job(&topo, &plan, &SyntheticApp::new(1.0), cfg, &inputs).metrics
}

/// (a) Identical seeds → bit-identical metrics with dynamics enabled.
#[test]
fn identical_seeds_give_bit_identical_metrics() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 24, 11);
    for profile in [DynProfile::Churn, DynProfile::Burst, DynProfile::Failures] {
        let runs: Vec<String> = (0..2)
            .map(|_| {
                let trace =
                    ScenarioTrace::generate(profile, 7, &TraceShape::of(&topo, 50.0));
                let cfg = JobConfig::dynamic_locality().with_dynamics(trace);
                let plan = Plan::local_push(&topo);
                let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
                sig(&run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs).metrics)
            })
            .collect();
        assert_eq!(runs[0], runs[1], "{profile:?}: dynamics run is nondeterministic");
    }
}

/// (b) A zero-event trace reproduces the static metrics bit-for-bit, for
/// both scheduler families, on a paper env and a generated platform.
#[test]
fn zero_event_trace_is_bit_identical_to_static() {
    // Paper environment.
    let topo = build_env(EnvKind::Global8);
    let plan = Plan::uniform(8, 8, 8);
    let inputs = synthetic_inputs(8, 1 << 15, 0x601D);
    for base in [JobConfig::default(), JobConfig::dynamic_locality()] {
        let stat = run_job(&topo, &plan, &SyntheticApp::new(1.0), &base, &inputs).metrics;
        let with_empty = base.clone().with_dynamics(ScenarioTrace::empty("none"));
        let empty = run_job(&topo, &plan, &SyntheticApp::new(1.0), &with_empty, &inputs).metrics;
        assert_eq!(sig(&stat), sig(&empty), "zero-event trace perturbed the engine");
    }
    // Generated platform, all kinds.
    for kind in ScaleKind::all() {
        let stat = small_job(kind, 16, 3, &JobConfig::default());
        let empty = small_job(
            kind,
            16,
            3,
            &JobConfig::default().with_dynamics(ScenarioTrace::empty("none")),
        );
        assert_eq!(sig(&stat), sig(&empty), "{kind:?}");
    }
}

/// (c) Failed-node tasks always complete elsewhere — no lost work, full
/// record conservation — under both scheduler families and across many
/// generated failure traces.
#[test]
fn failed_node_tasks_always_complete() {
    qcheck(Config::default().cases(12), "no lost work under failures", |rng| {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let plan = Plan::local_push(&topo);
        let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xFA11);
        let trace_seed = rng.next_u64();
        // Static run fixes the horizon so failures land mid-run.
        let stat = run_job(&topo, &plan, &SyntheticApp::new(1.0), &JobConfig::default(), &inputs)
            .metrics;
        let trace = ScenarioTrace::generate(
            DynProfile::Failures,
            trace_seed,
            &TraceShape::of(&topo, stat.makespan),
        );
        for (plan_local, base) in
            [(true, JobConfig::default()), (false, JobConfig::dynamic_locality())]
        {
            let cfg = base.clone().with_dynamics(trace.clone());
            let m = run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs).metrics;
            ensure(
                m.failures_injected > 0,
                format!("seed {trace_seed:#x}: trace injected no failure"),
            )?;
            // Shuffle byte conservation (restartable reduce): every
            // unique byte ends up credited exactly once — delivered or
            // dead-lettered — whatever was lost and replayed along the
            // way. Byte counts are integers < 2^53, so the f64 sums are
            // exact and equality is exact. (At the default retry budget
            // the seeded profiles never exhaust it: dlq_bytes is 0.)
            ensure(
                m.shuffle_bytes_delivered + m.dlq_bytes == m.shuffle_bytes,
                format!(
                    "seed {trace_seed:#x}: delivered {} + dlq {} != shuffled {} (replayed {})",
                    m.shuffle_bytes_delivered, m.dlq_bytes, m.shuffle_bytes,
                    m.reduce_bytes_replayed
                ),
            )?;
            ensure(m.dlq_bytes == 0.0, "default budget must absorb seeded failures")?;
            // Push-side conservation holds under every trace (no
            // refresh events here, so no re-push traffic either).
            ensure(
                m.push_bytes_delivered == m.push_bytes && m.push_bytes_repushed == 0.0,
                "push conservation broke under a failure trace",
            )?;
            ensure(
                m.input_records == stat.input_records,
                "input volume must match the static run",
            )?;
            ensure(
                m.output_records == m.input_records,
                format!(
                    "seed {trace_seed:#x}: lost records ({} in, {} out, {} requeued)",
                    m.input_records, m.output_records, m.tasks_requeued
                ),
            )?;
            if plan_local {
                // With the plan statically enforced a failure can only
                // delay the schedule (the dynamic policy, by contrast,
                // may beat the plan-local baseline outright).
                ensure(
                    m.makespan >= stat.makespan * 0.98,
                    format!(
                        "seed {trace_seed:#x}: failure sped up plan-local \
                         ({} vs {})",
                        m.makespan, stat.makespan
                    ),
                )?;
            }
        }
        Ok(())
    });
}

/// Recovery beats enforcement: with the plan's most-loaded mappers dead
/// from t=0 until well past the static makespan, the locality-aware
/// dynamic scheduler (steals the stranded splits) strictly beats
/// plan-local enforcement (waits for recovery). This is the
/// `experiment churn` headline, pinned deterministically.
#[test]
fn dynamic_locality_beats_plan_local_under_failures() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 32, 5);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 14, 0xBEEF);
    let app = SyntheticApp::new(1.0);
    // Small splits → several tasks per loaded mapper → stealable units.
    let mk = |base: JobConfig| JobConfig { split_size: 4 << 10, ..base };

    let static_m =
        run_job(&topo, &plan, &app, &mk(JobConfig::optimized()), &inputs).metrics;
    let s = static_m.makespan;
    assert!(s > 0.0);

    // The two mappers carrying the most planned load, dead from the
    // start, back long after the static run would have finished.
    let mut load: Vec<(f64, usize)> = (0..topo.n_mappers())
        .map(|j| ((0..topo.n_sources()).map(|i| plan.x.get(i, j)).sum::<f64>(), j))
        .collect();
    load.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let victims: Vec<usize> = load.iter().take(2).map(|&(_, j)| j).collect();
    assert!(load[0].0 > 0.0, "local-push plan must load some mapper");

    let mut events = Vec::new();
    for &v in &victims {
        events.push(TimedEvent { time: 0.0, event: DynEvent::MapperFail { node: v } });
        events.push(TimedEvent { time: 1.6 * s, event: DynEvent::MapperRecover { node: v } });
    }
    let trace = ScenarioTrace::from_events("targeted-outage", events);

    let pl = run_job(
        &topo,
        &plan,
        &app,
        &mk(JobConfig::optimized()).with_dynamics(trace.clone()),
        &inputs,
    )
    .metrics;
    let dl = run_job(
        &topo,
        &plan,
        &app,
        &mk(JobConfig {
            speculation: false, // isolate the stealing comparison
            ..JobConfig::dynamic_locality()
        })
        .with_dynamics(trace),
        &inputs,
    )
    .metrics;

    // Both complete everything.
    assert_eq!(pl.output_records, pl.input_records, "plan-local lost records");
    assert_eq!(dl.output_records, dl.input_records, "dynamic lost records");
    // Plan-local can only resume the stranded maps after recovery.
    assert!(
        pl.makespan > 1.6 * s,
        "plan-local should stall past recovery: {} vs static {s}",
        pl.makespan
    );
    // The dynamic policy steals the stranded work instead of waiting.
    assert!(dl.stolen > 0, "dynamic policy never stole");
    assert!(
        dl.makespan < pl.makespan,
        "dynamic+locality ({}) must beat plan-local ({}) under the outage",
        dl.makespan,
        pl.makespan
    );
}

/// Reducer-failure byte conservation for both scheduler families
/// (ISSUE 4 satellite): across generated failure traces — which now take
/// down reducers mid-run in addition to mappers — no shuffle byte is
/// lost or double-credited (`delivered == shuffled`, replay accounted
/// separately), records are conserved, and the stealing schedulers adopt
/// every orphaned key range while plan enforcement never does.
#[test]
fn reducer_failures_conserve_bytes_for_both_schedulers() {
    qcheck(Config::default().cases(10), "reducer-failure byte conservation", |rng| {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let plan = Plan::local_push(&topo);
        let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xFA11);
        let trace_seed = rng.next_u64();
        let stat = run_job(&topo, &plan, &SyntheticApp::new(1.0), &JobConfig::default(), &inputs)
            .metrics;
        let trace = ScenarioTrace::generate(
            DynProfile::Failures,
            trace_seed,
            &TraceShape::of(&topo, stat.makespan),
        );
        for (plan_local, base) in
            [(true, JobConfig::default()), (false, JobConfig::dynamic_locality())]
        {
            let cfg = base.clone().with_dynamics(trace.clone());
            let m = run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs).metrics;
            ensure(
                m.reducers_failed > 0,
                format!("seed {trace_seed:#x}: no reducer outage landed"),
            )?;
            ensure(
                m.shuffle_bytes_delivered + m.dlq_bytes == m.shuffle_bytes,
                format!(
                    "seed {trace_seed:#x} plan_local={plan_local}: delivered {} + dlq {} != \
                     shuffled {} (replayed {})",
                    m.shuffle_bytes_delivered, m.dlq_bytes, m.shuffle_bytes,
                    m.reduce_bytes_replayed
                ),
            )?;
            ensure(
                m.output_records == m.input_records,
                format!(
                    "seed {trace_seed:#x} plan_local={plan_local}: lost records \
                     ({} in, {} out)",
                    m.input_records, m.output_records
                ),
            )?;
            if plan_local {
                ensure(
                    m.reduce_ranges_reassigned == 0,
                    "plan enforcement must never re-partition a key range",
                )?;
            } else {
                ensure(
                    m.reduce_ranges_reassigned > 0,
                    format!(
                        "seed {trace_seed:#x}: stealing scheduler adopted no orphaned range \
                         ({} reducer failures)",
                        m.reducers_failed
                    ),
                )?;
            }
        }
        Ok(())
    });
}

/// Deterministic targeted reducer outage from t = 0: the plan-enforcing
/// run holds the dead reducer's key range for the whole outage window,
/// while the dynamic scheduler adopts it immediately and finishes far
/// earlier. Nothing was on the wire at failure time, so neither run
/// replays any bytes — pinning the first-send/replay accounting split.
#[test]
fn reducer_outage_stalls_plan_local_but_dynamic_adopts() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 32, 5);
    let plan = Plan::local_push(&topo); // uniform y: every range has mass
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 14, 0xBEEF);
    let app = SyntheticApp::new(1.0);

    let static_m = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;
    let s = static_m.makespan;
    assert!(s > 0.0);

    let victim = 0usize;
    let trace = ScenarioTrace::from_events(
        "targeted-reducer-outage",
        vec![
            TimedEvent { time: 0.0, event: DynEvent::ReducerFail { node: victim } },
            TimedEvent { time: 1.8 * s, event: DynEvent::ReducerRecover { node: victim } },
        ],
    );

    let pl = run_job(
        &topo,
        &plan,
        &app,
        &JobConfig::optimized().with_dynamics(trace.clone()),
        &inputs,
    )
    .metrics;
    let dl = run_job(
        &topo,
        &plan,
        &app,
        &JobConfig::dynamic_locality().with_dynamics(trace),
        &inputs,
    )
    .metrics;

    for m in [&pl, &dl] {
        assert_eq!(m.output_records, m.input_records, "lost records");
        assert_eq!(m.shuffle_bytes_delivered, m.shuffle_bytes, "lost bytes");
        assert_eq!(m.reducers_failed, 1);
        assert_eq!(
            m.reduce_bytes_replayed, 0.0,
            "nothing was on the wire at t=0, so nothing is a replay"
        );
    }
    assert_eq!(pl.reduce_ranges_reassigned, 0, "plan enforcement must wait");
    assert!(
        pl.makespan > 1.7 * s,
        "plan-local must stall until recovery: {} vs static {s}",
        pl.makespan
    );
    assert!(dl.reduce_ranges_reassigned >= 1, "dynamic must adopt the range");
    assert!(
        dl.makespan < pl.makespan,
        "adoption ({}) must beat waiting ({})",
        dl.makespan,
        pl.makespan
    );
}

/// Deterministic mid-reduce blackout: every reducer dies while reduce
/// compute is in flight (slow reducers guarantee nothing is durable yet
/// for the last ranges), so delivered data is genuinely lost and must be
/// replayed after recovery — `reduce_bytes_replayed > 0` — and under the
/// stealing scheduler the same-timestamp failure cascade re-partitions
/// ranges through the shrinking survivor set before stalling. Both
/// families still conserve every byte and record.
#[test]
fn full_reducer_blackout_replays_lost_bytes() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0x10AD);
    // Slow reduce: the reduce phase dominates, so a failure between
    // shuffle_end and makespan reliably catches non-durable ranges.
    let app = SyntheticApp::new(1.0).with_costs(1.0, 50.0);

    let stat = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;
    assert!(stat.makespan > stat.shuffle_end, "reduce phase must be non-trivial");
    let fail_at = 0.5 * (stat.shuffle_end + stat.makespan);
    let recover_at = 2.5 * stat.makespan;

    let mut events = Vec::new();
    for k in 0..topo.n_reducers() {
        events.push(TimedEvent { time: fail_at, event: DynEvent::ReducerFail { node: k } });
        events
            .push(TimedEvent { time: recover_at, event: DynEvent::ReducerRecover { node: k } });
    }
    let trace = ScenarioTrace::from_events("blackout", events);

    for (plan_local, base) in
        [(true, JobConfig::optimized()), (false, JobConfig::dynamic_locality())]
    {
        let cfg = base.clone().with_dynamics(trace.clone());
        let m = run_job(&topo, &plan, &app, &cfg, &inputs).metrics;
        assert_eq!(m.output_records, m.input_records, "plan_local={plan_local}");
        assert_eq!(m.shuffle_bytes_delivered, m.shuffle_bytes, "plan_local={plan_local}");
        assert_eq!(m.reducers_failed, topo.n_reducers(), "plan_local={plan_local}");
        assert!(
            m.reduce_bytes_replayed > 0.0,
            "plan_local={plan_local}: a blackout mid-reduce must force replays"
        );
        assert!(
            m.makespan > 2.0 * stat.makespan,
            "plan_local={plan_local}: the blackout must stall the job ({} vs {})",
            m.makespan,
            stat.makespan
        );
        if plan_local {
            assert_eq!(m.reduce_ranges_reassigned, 0);
        }
        // (Whether the stealing scheduler manages an adoption before the
        // cascade exhausts the survivor set depends on which ranges were
        // already durable; adoption itself is pinned deterministically in
        // reducer_outage_stalls_plan_local_but_dynamic_adopts.)
    }
}

/// Bandwidth-profile smoke: step/periodic/burst traces apply, never
/// meaningfully speed the job up, and leave record conservation intact.
#[test]
fn bandwidth_profiles_apply_and_conserve() {
    let topo = generate_kind(ScaleKind::FederatedDataCenters, 18, 9);
    // Uniform push exercises the WAN links the profiles degrade.
    let plan = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0x5EED);
    let app = SyntheticApp::new(1.0);
    let stat = run_job(&topo, &plan, &app, &JobConfig::default(), &inputs).metrics;
    for profile in [DynProfile::Step, DynProfile::Periodic, DynProfile::Burst] {
        let trace =
            ScenarioTrace::generate(profile, 4, &TraceShape::of(&topo, stat.makespan));
        let cfg = JobConfig::default().with_dynamics(trace);
        let m = run_job(&topo, &plan, &app, &cfg, &inputs).metrics;
        assert_eq!(m.output_records, stat.output_records, "{profile:?}");
        // Loose bound (max-min reallocation is not pointwise monotone,
        // but a WAN degradation must not meaningfully speed the job up).
        assert!(
            m.makespan >= stat.makespan * 0.95,
            "{profile:?}: degradation sped the job up ({} vs {})",
            m.makespan,
            stat.makespan
        );
        assert!(m.dyn_events > 0, "{profile:?}: no event applied");
    }
}

/// Staleness byte-conservation qcheck (ISSUE 5 tentpole): across
/// generated staleness traces — sources refreshing fractions of their
/// data mid-push — every push byte ends up credited exactly once
/// (`push_bytes_delivered == push_bytes`, re-push traffic accounted
/// separately in `push_bytes_repushed`), for both scheduler families,
/// with full record conservation. A uniform plan keeps the push phase
/// WAN-bound and long, so the early refreshes reliably land before the
/// splits seal.
#[test]
fn staleness_conserves_push_bytes_for_both_schedulers() {
    qcheck(Config::default().cases(10), "staleness push-byte conservation", |rng| {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let plan = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0x57A1);
        let trace_seed = rng.next_u64();
        let stat = run_job(&topo, &plan, &SyntheticApp::new(1.0), &JobConfig::default(), &inputs)
            .metrics;
        let trace = ScenarioTrace::generate(
            DynProfile::Staleness,
            trace_seed,
            &TraceShape::of(&topo, stat.makespan),
        );
        for base in [JobConfig::default(), JobConfig::dynamic_locality()] {
            let cfg = base.clone().with_dynamics(trace.clone());
            let m = run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs).metrics;
            ensure(
                m.sources_refreshed > 0,
                format!("seed {trace_seed:#x}: no refresh landed mid-push"),
            )?;
            ensure(
                m.push_bytes_repushed > 0.0,
                format!("seed {trace_seed:#x}: a landed refresh must re-push bytes"),
            )?;
            // Exact conservation: byte counts are integers < 2^53, so
            // the f64 sums are exact and equality is exact.
            ensure(
                m.push_bytes_delivered == m.push_bytes,
                format!(
                    "seed {trace_seed:#x}: delivered {} != pushed {} (repushed {})",
                    m.push_bytes_delivered, m.push_bytes, m.push_bytes_repushed
                ),
            )?;
            ensure(
                m.push_bytes == stat.push_bytes,
                "re-pushes must not inflate the base push_bytes account",
            )?;
            // The shuffle-side invariant must survive staleness too.
            ensure(
                m.shuffle_bytes_delivered == m.shuffle_bytes,
                "shuffle conservation broke under staleness",
            )?;
            ensure(
                m.output_records == m.input_records,
                format!(
                    "seed {trace_seed:#x}: lost records ({} in, {} out)",
                    m.input_records, m.output_records
                ),
            )?;
        }
        Ok(())
    });
}

/// Deterministic full-refresh pin: every source refreshes 100% of its
/// data while the push is mid-flight, under the default Global push→map
/// barrier (no split has sealed). Every transfer is therefore stale and
/// re-sent exactly once more: `push_bytes_repushed == push_bytes`
/// exactly, the conservation invariant holds, and the re-push visibly
/// delays the WAN-bound job. Also pins same-config determinism.
#[test]
fn full_refresh_repushes_every_byte_exactly_once() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xF8E5);
    let app = SyntheticApp::new(1.0);

    let stat = run_job(&topo, &plan, &app, &JobConfig::default(), &inputs).metrics;
    assert!(stat.push_end > 0.0);
    let t0 = 0.5 * stat.push_end;
    let events: Vec<TimedEvent> = (0..topo.n_sources())
        .map(|i| TimedEvent {
            time: t0,
            event: DynEvent::SourceRefresh { source: i, fraction: 1.0 },
        })
        .collect();
    let trace = ScenarioTrace::from_events("full-refresh", events);

    let run = || {
        run_job(
            &topo,
            &plan,
            &app,
            &JobConfig::default().with_dynamics(trace.clone()),
            &inputs,
        )
        .metrics
    };
    let m = run();
    assert_eq!(m.sources_refreshed, topo.n_sources(), "every refresh must land");
    assert_eq!(
        m.push_bytes_repushed, m.push_bytes,
        "a 100% refresh of every source mid-push re-sends exactly every byte once"
    );
    assert_eq!(m.push_bytes, stat.push_bytes);
    assert_eq!(m.push_bytes_delivered, m.push_bytes, "conservation");
    assert_eq!(m.output_records, m.input_records);
    assert!(
        m.makespan > stat.makespan,
        "re-pushing the whole WAN-bound input must cost time ({} vs {})",
        m.makespan,
        stat.makespan
    );
    // Same config, same trace → bit-identical metrics.
    assert_eq!(sig(&m), sig(&run()), "staleness run is nondeterministic");
}

/// A refresh landing after the push completed is a no-op: the splits
/// sealed, the job ran to completion on its consistent snapshot, and
/// the metrics besides dyn_events are bit-identical to the static run.
#[test]
fn late_refresh_is_a_noop() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0x1A7E);
    let app = SyntheticApp::new(1.0);
    let stat = run_job(&topo, &plan, &app, &JobConfig::default(), &inputs).metrics;
    let trace = ScenarioTrace::from_events(
        "late-refresh",
        vec![TimedEvent {
            time: stat.push_end * 1.01,
            event: DynEvent::SourceRefresh { source: 0, fraction: 1.0 },
        }],
    );
    let m = run_job(
        &topo,
        &plan,
        &app,
        &JobConfig::default().with_dynamics(trace),
        &inputs,
    )
    .metrics;
    assert_eq!(m.sources_refreshed, 0, "sealed splits must not re-dirty");
    assert_eq!(m.push_bytes_repushed, 0.0);
    // The event boundary re-accumulates partial fluid progress, so the
    // makespan may differ by ulps from the static run — but no more.
    assert!(
        (m.makespan - stat.makespan).abs() <= 1e-9 * stat.makespan,
        "no-op refresh changed the makespan: {} vs {}",
        m.makespan,
        stat.makespan
    );
    assert_eq!(m.push_bytes_delivered, m.push_bytes);
    assert_eq!(m.output_records, stat.output_records);
}

/// Straggler smoke: a slowdown trace applies cleanly under the dynamic
/// scheduler (whether speculation actually fires depends on timing; the
/// deterministic trigger is unit-tested in engine::scheduler).
#[test]
fn straggler_trace_smoke() {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 24, 2);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 14, 0x57A6);
    let app = SyntheticApp::new(1.0);
    let small_splits = |base: JobConfig| JobConfig { split_size: 4 << 10, ..base };
    let stat =
        run_job(&topo, &plan, &app, &small_splits(JobConfig::default()), &inputs).metrics;
    let trace = ScenarioTrace::generate(
        DynProfile::Stragglers,
        3,
        &TraceShape::of(&topo, stat.makespan),
    );
    // Plan-local run: the schedule cannot outrun the trace, so at least
    // one slowdown must land mid-run.
    let cfg = small_splits(JobConfig::default()).with_dynamics(trace.clone());
    let m = run_job(&topo, &plan, &app, &cfg, &inputs).metrics;
    assert_eq!(m.output_records, stat.output_records);
    assert!(m.dyn_events > 0, "no slowdown applied under plan-local");
    // Dynamic run: conservation under the same trace (whether its
    // events land before this faster schedule finishes is timing-
    // dependent, so only correctness is asserted).
    let cfg = small_splits(JobConfig::dynamic_locality()).with_dynamics(trace);
    let m = run_job(&topo, &plan, &app, &cfg, &inputs).metrics;
    assert_eq!(m.output_records, stat.output_records);
}
