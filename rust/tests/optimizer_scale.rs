//! ISSUE 2 acceptance tests: the scalable optimizer stack.
//!
//! * Oracle: the sparse revised simplex matches the dense tableau
//!   objective (≤1e-7 relative) on every LP shape the paper environments
//!   generate.
//! * Property: the analytic reverse-mode gradient agrees with central
//!   finite differences (≤1e-5 relative to the gradient's max-norm) on
//!   random instances across all three barrier configurations.
//! * Warm starts re-solve to the same optimum on the sparse path.
//! * End to end: both e2e optimizers produce valid, uniform-beating plans
//!   on 64-node generated topologies, and the accelerated path matches
//!   the legacy path's plan quality.
//!
//! (The wall-clock acceptance — ≥10× at 64 nodes, <30 s at 256 — is
//! asserted by `cargo bench`, release mode; see benches/bench_main.rs.)

use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{makespan, AppModel};
use mrperf::model::plan::Plan;
use mrperf::model::smooth::smooth_makespan_grad;
use mrperf::optimizer::gradient::{FiniteDiffBackend, GradBackend};
use mrperf::optimizer::lp_build::{build_lp_x, build_lp_y, extract_x, Objective};
use mrperf::optimizer::{AlternatingLp, GradientOptimizer, PlanOptimizer};
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::topology::{Continent, Topology, TopologyBuilder};
use mrperf::platform::{build_env, EnvKind};
use mrperf::solver::lp::Lp;
use mrperf::util::mat::Mat;
use mrperf::util::qcheck::{ensure, qcheck, Config};
use mrperf::util::rng::Pcg64;

const CFGS: [BarrierConfig; 3] = [
    BarrierConfig::ALL_GLOBAL,
    BarrierConfig::HADOOP,
    BarrierConfig::ALL_PIPELINED,
];

// ------------------------------------------------------------------ oracle

fn assert_solvers_agree(lp: &Lp, ctx: &str) {
    let (xd, od) = mrperf::solver::solve_robust_dense(lp).expect_optimal(ctx);
    let (xs, os) = mrperf::solver::revised::solve(lp).expect_optimal(ctx);
    assert!(
        lp.violation(&xs) < 1e-6,
        "{ctx}: revised violation {}",
        lp.violation(&xs)
    );
    assert!(
        lp.violation(&xd) < 1e-6,
        "{ctx}: dense violation {}",
        lp.violation(&xd)
    );
    assert!(
        (od - os).abs() <= 1e-7 * od.abs().max(1.0),
        "{ctx}: dense objective {od} vs revised {os}"
    );
}

/// The satellite oracle check: both solvers on every LP shape the paper
/// environments generate (x-LPs over uniform / one-hot / random shuffle
/// splits, y-LPs over the local-push x, plus the myopic objectives).
#[test]
fn revised_simplex_matches_dense_on_paper_env_lps() {
    let mut rng = Pcg64::new(0xE2E);
    let app = AppModel::new(1.3);
    for kind in EnvKind::all() {
        let t = build_env(kind);
        let r = t.n_reducers();
        for cfg in CFGS {
            let mut ys: Vec<Vec<f64>> = vec![vec![1.0 / r as f64; r]];
            let mut one_hot = vec![0.0; r];
            one_hot[0] = 1.0;
            ys.push(one_hot);
            let mut yr: Vec<f64> = (0..r).map(|_| rng.exponential(1.0)).collect();
            let sum: f64 = yr.iter().sum();
            yr.iter_mut().for_each(|v| *v /= sum);
            ys.push(yr);
            for (yi, y) in ys.iter().enumerate() {
                let (lp, _) = build_lp_x(&t, app, cfg, y, Objective::Makespan);
                assert_solvers_agree(&lp, &format!("{kind:?}/{}/lp_x[y{yi}]", cfg.label()));
            }
            let x = Plan::local_push(&t).x;
            let (lp, _) = build_lp_y(&t, app, cfg, &x, Objective::Makespan);
            assert_solvers_agree(&lp, &format!("{kind:?}/{}/lp_y", cfg.label()));
        }
    }
    // Myopic objectives (Global8 covers the shape; they are cfg-light).
    let t = build_env(EnvKind::Global8);
    let y = vec![0.125; 8];
    let (lp, _) = build_lp_x(&t, app, BarrierConfig::ALL_GLOBAL, &y, Objective::PushTime);
    assert_solvers_agree(&lp, "global8/lp_x[push-time]");
    let x = Plan::uniform(8, 8, 8).x;
    let (lp, _) = build_lp_y(&t, app, BarrierConfig::ALL_GLOBAL, &x, Objective::ShuffleEnd);
    assert_solvers_agree(&lp, "global8/lp_y[shuffle-end]");
}

// -------------------------------------------------------- analytic gradient

/// Small random multi-cluster topology for gradient property testing.
fn random_small_topo(rng: &mut Pcg64) -> Topology {
    let n_clusters = rng.range(2, 4);
    let mut b = TopologyBuilder::new("qc-topo");
    for c in 0..n_clusters {
        b.cluster(&format!("c{c}"), Continent::US);
    }
    let s = rng.range(2, 5);
    let m = rng.range(2, 5);
    let r = rng.range(2, 5);
    for i in 0..s {
        b.source(i % n_clusters, rng.uniform(10.0, 200.0) * 1e9);
    }
    for j in 0..m {
        b.mapper(j % n_clusters, rng.uniform(20.0, 120.0) * 1e6);
    }
    for k in 0..r {
        b.reducer(k % n_clusters, rng.uniform(20.0, 120.0) * 1e6);
    }
    let mut bw = vec![vec![0.0f64; n_clusters]; n_clusters];
    for (a, row) in bw.iter_mut().enumerate() {
        for (c2, v) in row.iter_mut().enumerate() {
            *v = if a == c2 { 120.0e6 } else { rng.uniform(2.0, 40.0) * 1e6 };
        }
    }
    b.build_with_bandwidth(|a, c2| bw[a][c2])
}

/// The satellite property: analytic gradients agree with central finite
/// differences to 1e-5 (relative to the gradient max-norm) on random
/// instances, for all three barrier configurations.
#[test]
fn qcheck_analytic_gradient_matches_finite_differences() {
    qcheck(
        Config::default().cases(25).seed(0x6AD2),
        "analytic gradient vs finite differences",
        |rng: &mut Pcg64| {
            let t = random_small_topo(rng);
            let (s, m, r) = (t.n_sources(), t.n_mappers(), t.n_reducers());
            let mut lx = Mat::zeros(s, m);
            for i in 0..s {
                for j in 0..m {
                    lx.set(i, j, rng.normal() * 0.7);
                }
            }
            let ly: Vec<f64> = (0..r).map(|_| rng.normal() * 0.7).collect();
            let app = AppModel::new(rng.uniform(0.2, 5.0));
            for cfg in CFGS {
                let uni = makespan(&t, app, cfg, &Plan::uniform(s, m, r));
                let beta = 50.0 / uni;
                let (la, gx, gy) = smooth_makespan_grad(&t, app, cfg, &lx, &ly, beta);
                let mut fd = FiniteDiffBackend::default();
                let (lf, fx, fy) = fd.value_and_grad(&t, app, cfg, &lx, &ly, beta);
                ensure(
                    (la - lf).abs() <= 1e-9 * lf.abs().max(1.0),
                    format!("{}: loss {la} vs fd {lf}", cfg.label()),
                )?;
                let gmax = gx
                    .data()
                    .iter()
                    .chain(&gy)
                    .fold(0.0f64, |a, &g| a.max(g.abs()))
                    .max(1e-12);
                for i in 0..s {
                    for j in 0..m {
                        let rel = (gx.get(i, j) - fx.get(i, j)).abs() / gmax;
                        ensure(
                            rel < 1e-5,
                            format!(
                                "{}: gx[{i}][{j}] {} vs fd {} (rel {rel})",
                                cfg.label(),
                                gx.get(i, j),
                                fx.get(i, j)
                            ),
                        )?;
                    }
                }
                for k in 0..r {
                    let rel = (gy[k] - fy[k]).abs() / gmax;
                    ensure(
                        rel < 1e-5,
                        format!("{}: gy[{k}] {} vs fd {} (rel {rel})", cfg.label(), gy[k], fy[k]),
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------- warm starts

#[test]
fn sparse_warm_start_consistent_on_64node_lp() {
    let t = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
    let app = AppModel::new(1.0);
    let cfg = BarrierConfig::ALL_GLOBAL;
    let r = t.n_reducers();
    let y = vec![1.0 / r as f64; r];
    let (lp, vars) = build_lp_x(&t, app, cfg, &y, Objective::Makespan);
    assert!(
        lp.n_rows() > mrperf::solver::DENSE_ROW_CUTOVER,
        "64-node x-LP must exercise the sparse path ({} rows)",
        lp.n_rows()
    );
    let (cold, basis) = mrperf::solver::solve_smart(&lp, None);
    let (xc, oc) = cold.expect_optimal("cold sparse solve");
    assert!(lp.violation(&xc) < 1e-6, "violation {}", lp.violation(&xc));
    let basis = basis.expect("sparse path returns its basis");
    let (warm, _) = mrperf::solver::solve_smart(&lp, Some(&basis));
    let (_, ow) = warm.expect_optimal("warm sparse solve");
    assert!(
        (oc - ow).abs() <= 1e-7 * oc.abs().max(1.0),
        "cold {oc} vs warm {ow}"
    );
    // The LP objective is the exact model makespan of the extracted plan
    // (formulation consistency at scale)…
    let mut p = Plan { x: extract_x(&xc, &vars), y: y.clone() };
    p.renormalize();
    let ms = makespan(&t, app, cfg, &p);
    assert!(
        (ms - oc).abs() <= 1e-5 * oc.max(1.0),
        "LP objective {oc} vs model {ms}"
    );
    // …and no heuristic x beats the LP optimum for this y.
    let mut local = Plan::local_push(&t);
    local.y = y;
    assert!(oc <= makespan(&t, app, cfg, &local) + 1e-6);
}

// ------------------------------------------------------------- end to end

#[test]
fn optimizers_scale_to_64_nodes_and_beat_uniform() {
    let t = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
    let (s, m, r) = (t.n_sources(), t.n_mappers(), t.n_reducers());
    let app = AppModel::new(2.0);
    for cfg in [BarrierConfig::ALL_GLOBAL, BarrierConfig::HADOOP] {
        let uni = makespan(&t, app, cfg, &Plan::uniform(s, m, r));
        let alt = AlternatingLp::default().optimize(&t, app, cfg);
        alt.check(&t).unwrap();
        let ms_alt = makespan(&t, app, cfg, &alt);
        assert!(ms_alt <= uni + 1e-6, "{}: alternating {ms_alt} vs uniform {uni}", cfg.label());
        let grad = GradientOptimizer::default().optimize(&t, app, cfg);
        grad.check(&t).unwrap();
        let ms_grad = makespan(&t, app, cfg, &grad);
        assert!(ms_grad <= uni + 1e-6, "{}: gradient {ms_grad} vs uniform {uni}", cfg.label());
        // On WAN-bottlenecked topologies the optimizers must genuinely
        // improve on uniform, not just tie it.
        assert!(ms_alt < uni * 0.9, "{}: alternating should beat uniform by >10%", cfg.label());
    }
}

#[test]
fn accel_path_matches_legacy_quality_at_32_nodes() {
    let t = generate_kind(ScaleKind::HierarchicalWan, 32, 5);
    let app = AppModel::new(2.0);
    let cfg = BarrierConfig::HADOOP;
    let fast = AlternatingLp { random_starts: 0, max_rounds: 4, ..Default::default() };
    let slow = AlternatingLp { accel: false, ..fast };
    let pf = fast.optimize(&t, app, cfg);
    pf.check(&t).unwrap();
    let ps = slow.optimize(&t, app, cfg);
    ps.check(&t).unwrap();
    let mf = makespan(&t, app, cfg, &pf);
    let ml = makespan(&t, app, cfg, &ps);
    assert!(
        mf <= ml * 1.05 + 1e-9,
        "accel plan {mf} must match legacy plan {ml} quality"
    );
}
