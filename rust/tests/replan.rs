//! Online re-optimization properties (ISSUE 10) — the replan test tier
//! pinning the `engine::replan` invariants:
//!
//! * **neutrality** — `--replan off` (and the absent flag) is
//!   bit-identical to the static engine across every dynamics profile
//!   and both pre-existing scheduler families, and a zero-event trace
//!   with replanning *on* never re-solves;
//! * **replanning pays** — under a targeted mid-push WAN cut 10× on the
//!   planned-best reducer cluster, the on-event replanner strictly
//!   beats the static plan-local run, with the exact push/shuffle
//!   byte-conservation ledgers intact post-migration;
//! * **determinism** — same seeds → bit-identical metrics, for
//!   `on-event` and `every:T` alike, and invariant under the fluid
//!   thread count;
//! * **warm starts pay** — a second replan re-solve spends strictly
//!   fewer simplex iterations than a cold solve of the same LP
//!   sequence, and the replanned x-LP agrees with the dense-tableau
//!   oracle to ≤ 1e-7.
//!
//! The checkpoint/resume composition tests live in tests/recovery.rs.

use std::sync::Mutex;

use mrperf::apps::SyntheticApp;
use mrperf::engine::dynamics::{DynEvent, DynProfile, ScenarioTrace, TimedEvent, TraceShape};
use mrperf::engine::job::{batch_size, JobConfig};
use mrperf::engine::{run_job, JobMetrics, ReplanPolicy};
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::AppModel;
use mrperf::model::plan::Plan;
use mrperf::optimizer::lp_build::{build_lp_x, Objective};
use mrperf::optimizer::{AlternatingLp, PlanOptimizer, Replanner};
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::Topology;
use mrperf::util::qcheck::{ensure, qcheck, Config};

/// Serializes the tests that read the process-wide solver hot-path
/// counters (`solver::hot_path_counters`), so a concurrently running
/// sparse solve elsewhere in this binary cannot pollute the deltas.
/// Poison-tolerant: a panicked holder must not cascade.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

/// Bit-exact signature of every metric field (floats by bit pattern).
/// `coordinator_restarts` and `replans_skipped` are deliberately
/// excluded: both are provenance (crashes survived, re-solve
/// evaluations declined — a resume re-evaluates one boundary), and the
/// checkpoint/resume invariant is exactly that everything else matches
/// bit for bit. Accepted replans and the migration counters ARE part of
/// the identity: a resumed replanning run must replay them exactly.
fn sig(m: &JobMetrics) -> String {
    format!(
        "{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{:x}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        m.makespan.to_bits(),
        m.push_end.to_bits(),
        m.map_end.to_bits(),
        m.shuffle_end.to_bits(),
        m.push_bytes.to_bits(),
        m.shuffle_bytes.to_bits(),
        m.output_bytes.to_bits(),
        m.reduce_bytes_replayed.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_repushed.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.dlq_bytes.to_bits(),
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.spec_launched,
        m.spec_won,
        m.stolen,
        m.dyn_events,
        m.failures_injected,
        m.tasks_requeued,
        m.reducers_failed,
        m.reduce_ranges_reassigned,
        m.sources_refreshed,
        m.splits_dead_lettered,
        m.ranges_dead_lettered,
        m.input_records,
        m.intermediate_records,
        m.output_records,
        m.replans,
        m.replan_migrated_splits,
        m.replan_migrated_ranges
    )
}

/// No re-solve ever happened and no work was re-homed by one.
fn assert_no_replan_activity(m: &JobMetrics, what: &str) {
    assert_eq!(
        (m.replans, m.replans_skipped, m.replan_migrated_splits, m.replan_migrated_ranges),
        (0, 0, 0, 0),
        "{what}: replan machinery touched a run it must not touch"
    );
}

/// The exact byte-conservation ledgers (integer byte counts in f64, so
/// the sums are exact and equality is exact).
fn assert_conservation(m: &JobMetrics, what: &str) {
    assert_eq!(
        m.push_bytes_delivered.to_bits(),
        m.push_bytes.to_bits(),
        "{what}: push ledger broken (delivered {} != pushed {})",
        m.push_bytes_delivered,
        m.push_bytes
    );
    assert_eq!(
        (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits(),
        m.shuffle_bytes.to_bits(),
        "{what}: shuffle ledger broken (delivered {} + dlq {} != shuffled {})",
        m.shuffle_bytes_delivered,
        m.dlq_bytes,
        m.shuffle_bytes
    );
    assert_eq!(m.output_records, m.input_records, "{what}: records lost");
}

fn small_platform() -> (Topology, Plan, Vec<Vec<mrperf::engine::Record>>) {
    let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let plan = Plan::local_push(&topo);
    let inputs = synthetic_inputs(topo.n_sources(), 1 << 13, 0xD11A);
    (topo, plan, inputs)
}

/// (a) Neutrality: `ReplanPolicy::Off` — the default and the absent CLI
/// flag — is bit-identical to the pre-replan engine under EVERY
/// dynamics profile, for both the plan-local and the dynamic scheduler
/// family.
#[test]
fn replan_off_is_bit_identical_for_every_profile_and_family() {
    let (topo, plan, inputs) = small_platform();
    let app = SyntheticApp::new(1.0);
    let stat = run_job(&topo, &plan, &app, &JobConfig::default(), &inputs).metrics;
    for profile in DynProfile::all() {
        let trace =
            ScenarioTrace::generate(profile, 7, &TraceShape::of(&topo, stat.makespan));
        for base in [JobConfig::optimized(), JobConfig::dynamic_locality()] {
            let plain = base.clone().with_dynamics(trace.clone());
            let explicit_off =
                base.clone().with_dynamics(trace.clone()).with_replan(ReplanPolicy::Off, 1.0);
            let a = run_job(&topo, &plan, &app, &plain, &inputs).metrics;
            let b = run_job(&topo, &plan, &app, &explicit_off, &inputs).metrics;
            assert_eq!(sig(&a), sig(&b), "{profile:?}: --replan off diverged");
            assert_no_replan_activity(&a, "flag-absent");
            assert_no_replan_activity(&b, "explicit off");
        }
    }
}

/// (b) A zero-event trace with replanning ON never re-solves. Under
/// `on-event` no boundary ever fires, so the run is bit-identical to
/// the static engine; under `every:T` the cadence boundaries do fire,
/// but the unchanged platform is inside the hysteresis band — every
/// evaluation declines (the extra fluid-advance split points can move
/// float results by ulps, so the cadence run asserts counters and a
/// tight relative makespan bound rather than bit identity).
#[test]
fn zero_event_trace_with_replanning_on_never_resolves() {
    let (topo, plan, inputs) = small_platform();
    let app = SyntheticApp::new(1.0);
    let stat = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;

    let on_event = JobConfig::optimized()
        .with_dynamics(ScenarioTrace::empty("none"))
        .with_replan(ReplanPolicy::OnEvent, 1.0);
    let m = run_job(&topo, &plan, &app, &on_event, &inputs).metrics;
    assert_eq!(sig(&stat), sig(&m), "on-event with no events must be the static engine");
    assert_no_replan_activity(&m, "on-event, zero-event trace");

    let every = JobConfig::optimized()
        .with_dynamics(ScenarioTrace::empty("none"))
        .with_replan(ReplanPolicy::Every(stat.makespan / 7.0), 1.0);
    let m = run_job(&topo, &plan, &app, &every, &inputs).metrics;
    assert_eq!(m.replans, 0, "an unchanged platform must never be re-solved");
    assert_eq!((m.replan_migrated_splits, m.replan_migrated_ranges), (0, 0));
    assert!(m.replans_skipped > 0, "the cadence must actually have evaluated");
    assert!(
        (m.makespan - stat.makespan).abs() <= 1e-9 * stat.makespan,
        "cadence ticks perturbed the makespan: {} vs {}",
        m.makespan,
        stat.makespan
    );
    assert_conservation(&m, "every:T, zero-event trace");
}

/// (c) The deterministic pin where replanning PAYS: a shuffle-dominant
/// job (α = 4), planned end-to-end, then hit mid-push by a 10× WAN cut
/// targeted at exactly the cluster the plan sends the most shuffle mass
/// to. Under G-P-L barriers nothing has shuffled yet, so the accepted
/// re-solve migrates key ranges off the cut cluster and the replanning
/// run strictly beats the static plan-local run — with every byte
/// ledger exact after the migration.
#[test]
fn targeted_wan_cut_replan_strictly_beats_static() {
    let alpha = 4.0;
    let gen = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
    let inputs = synthetic_inputs(gen.n_sources(), 1 << 13, 0xD11A);
    // Price the model on the simulated volume (the fig4 idiom) so the
    // optimizer's plan is meaningful for the engine run.
    let mean =
        inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / gen.n_sources() as f64;
    let topo = gen.with_uniform_data(mean);
    let am = AppModel::new(alpha);
    let bc = BarrierConfig::HADOOP;
    let plan = AlternatingLp::default().optimize(&topo, am, bc);
    let app = SyntheticApp::new(alpha);

    let static_cfg = JobConfig::optimized();
    let quiet = run_job(&topo, &plan, &app, &static_cfg, &inputs).metrics;
    assert!(quiet.push_end > 0.0);

    // The cluster receiving the largest planned shuffle mass.
    let best = (0..topo.n_reducers())
        .max_by(|&a, &b| plan.y[a].total_cmp(&plan.y[b]))
        .unwrap();
    let cluster = topo.reducer_cluster[best];
    let trace = ScenarioTrace::from_events(
        "targeted-cut",
        vec![TimedEvent {
            time: quiet.push_end * 0.5,
            event: DynEvent::ClusterLinkScale { cluster, factor: 0.1 },
        }],
    );

    let static_m =
        run_job(&topo, &plan, &app, &static_cfg.clone().with_dynamics(trace.clone()), &inputs)
            .metrics;
    let replan_cfg = static_cfg
        .clone()
        .with_dynamics(trace)
        .with_replan(ReplanPolicy::OnEvent, alpha);
    let replan_m = run_job(&topo, &plan, &app, &replan_cfg, &inputs).metrics;

    assert!(replan_m.replans >= 1, "the cut must trigger a re-solve: {replan_m:?}");
    assert!(
        replan_m.replan_migrated_ranges > 0,
        "the re-solve must move shuffle mass off the cut cluster: {replan_m:?}"
    );
    assert!(
        replan_m.makespan < static_m.makespan,
        "replanning must strictly beat the static plan under the targeted cut: \
         replan {} vs static {}",
        replan_m.makespan,
        static_m.makespan
    );
    assert_conservation(&static_m, "static under cut");
    assert_conservation(&replan_m, "replanning under cut");
}

/// (d) Determinism: same `(platform seed, trace seed)` → bit-identical
/// metrics for both replan policies, and invariant under the fluid
/// solver's thread count (`--threads 1` vs `--threads 4`).
#[test]
fn replanning_is_deterministic_and_thread_invariant() {
    let (topo, plan, inputs) = small_platform();
    let app = SyntheticApp::new(1.0);
    let stat = run_job(&topo, &plan, &app, &JobConfig::optimized(), &inputs).metrics;
    qcheck(Config::default().cases(6), "replan determinism", |rng| {
        let trace_seed = rng.next_u64();
        let trace = ScenarioTrace::generate(
            DynProfile::Failures,
            trace_seed,
            &TraceShape::of(&topo, stat.makespan),
        );
        for policy in [ReplanPolicy::OnEvent, ReplanPolicy::Every(stat.makespan / 5.0)] {
            let mk = |threads: usize| {
                let cfg = JobConfig { threads, ..JobConfig::optimized() }
                    .with_dynamics(trace.clone())
                    .with_replan(policy, 1.0);
                run_job(&topo, &plan, &app, &cfg, &inputs).metrics
            };
            let (a, b, c) = (mk(1), mk(1), mk(4));
            ensure(
                sig(&a) == sig(&b),
                format!("seed {trace_seed:#x} {policy:?}: replanning run is nondeterministic"),
            )?;
            ensure(
                sig(&a) == sig(&c),
                format!("seed {trace_seed:#x} {policy:?}: thread count changed the results"),
            )?;
            ensure(
                a.replans_skipped == b.replans_skipped && a.replans_skipped == c.replans_skipped,
                format!("seed {trace_seed:#x} {policy:?}: skip provenance diverged"),
            )?;
        }
        Ok(())
    });
}

/// (e) Warm starts pay: on the sparse-solver-sized platform (64 nodes —
/// the x-LP is above `DENSE_ROW_CUTOVER`), a second replan against a
/// perturbed platform spends strictly fewer simplex iterations than a
/// cold replanner solving exactly the same LP sequence, because the
/// previous optimal basis is nearly feasible for the perturbed LP. The
/// replanned x-LP also agrees with the dense-tableau oracle to ≤ 1e-7.
#[test]
fn warm_started_replans_solve_fewer_iterations_than_cold() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let topo = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
    let am = AppModel::new(1.0);
    let bc = BarrierConfig::HADOOP;
    let r = topo.n_reducers();
    let y0 = vec![1.0 / r as f64; r];

    // First (cold) descent populates the warm-start bases.
    let mut warm = Replanner::default();
    let p1 = warm.replan(&topo, am, bc, &y0).expect("64-node replan must solve");
    assert!(
        warm.x_basis.is_some(),
        "the 64-node x-LP must take the sparse revised path and return a basis"
    );

    // An asymmetrically perturbed platform (one half of the WAN shuffle
    // links 10% slower) — the kind of effective topology a mid-run
    // event produces.
    let mut topo2 = topo.clone();
    for j in 0..topo2.n_mappers() {
        for k in 0..r / 2 {
            topo2.b_mr.set(j, k, topo2.b_mr.get(j, k) * 0.9);
        }
    }

    mrperf::solver::reset_hot_path_counters();
    let p2 = warm.replan(&topo2, am, bc, &p1.y).expect("perturbed replan must solve");
    let (warm_iters, _) = mrperf::solver::hot_path_counters();

    mrperf::solver::reset_hot_path_counters();
    let mut cold = Replanner::default();
    let p3 = cold.replan(&topo2, am, bc, &p1.y).expect("cold replan must solve");
    let (cold_iters, _) = mrperf::solver::hot_path_counters();

    assert!(warm_iters > 0 && cold_iters > 0, "{warm_iters} / {cold_iters}");
    assert!(
        warm_iters < cold_iters,
        "warm-started re-solve must spend strictly fewer simplex iterations: \
         warm {warm_iters} vs cold {cold_iters}"
    );
    p2.check(&topo2).expect("warm plan valid");
    p3.check(&topo2).expect("cold plan valid");

    // Oracle: the replanned x-LP solved sparse agrees with the dense
    // tableau on the objective to ≤ 1e-7 (relative).
    let (lp, _) = build_lp_x(&topo2, am, bc, &p2.y, Objective::Makespan);
    let (_, dense_obj) =
        mrperf::solver::simplex::solve(&lp).optimal().expect("dense oracle solves");
    let (_, sparse_obj) =
        mrperf::solver::revised::solve(&lp).optimal().expect("sparse path solves");
    let denom = dense_obj.abs().max(1.0);
    assert!(
        (dense_obj - sparse_obj).abs() <= 1e-7 * denom,
        "revised-vs-dense oracle drift: {sparse_obj} vs {dense_obj}"
    );
}
