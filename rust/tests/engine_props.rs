//! Engine-core property tests and golden regression pins (ISSUE 1):
//!
//! * the event heap pops in non-decreasing virtual time;
//! * the partitioner conserves bytes under any plan's `y`;
//! * scheduler policies never exceed per-node slot capacity;
//! * record conservation holds on generated large topologies;
//! * golden metrics on the four paper environments pin the refactored
//!   engine's behavior (self-blessing on first run, byte-exact and
//!   bit-deterministic afterwards).

use std::fmt::Write as _;

use mrperf::apps::SyntheticApp;
use mrperf::engine::events::EventQueue;
use mrperf::engine::job::{batch_size, JobConfig, Record};
use mrperf::engine::run_job;
use mrperf::engine::scheduler::{
    Assignment, DynamicScheduler, PlanLocalScheduler, RunningTask, SchedView, Scheduler,
};
use mrperf::engine::Partitioner;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::plan::Plan;
use mrperf::platform::scale::{generate_kind, ScaleKind};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::qcheck::{ensure, qcheck, Config};
use mrperf::util::rng::Pcg64;

// ---------------------------------------------------------------- events

/// Property: pops are ordered by virtual time even under adversarial
/// interleavings of pushes (including pushes dated in the past, which
/// the queue clamps to its clock), and nothing is lost.
#[test]
fn event_heap_pops_in_nondecreasing_virtual_time() {
    qcheck(Config::default().cases(200), "event heap ordering", |rng: &mut Pcg64| {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut last = f64::NEG_INFINITY;
        let mut pushed = 0u32;
        let mut popped = 0usize;
        for _ in 0..rng.range(1, 80) {
            if rng.chance(0.6) || q.is_empty() {
                q.push(rng.uniform(0.0, 100.0), pushed);
                pushed += 1;
            } else {
                let (t, _) = q.pop().unwrap();
                ensure(t >= last, format!("pop at {t} after {last}"))?;
                last = t;
                popped += 1;
            }
        }
        while let Some((t, _)) = q.pop() {
            ensure(t >= last, format!("drain pop at {t} after {last}"))?;
            last = t;
            popped += 1;
        }
        ensure(popped == pushed as usize, "every pushed event is delivered")?;
        Ok(())
    });
}

// ----------------------------------------------------------- partitioner

/// Property: routing records through the bucketized partitioner loses no
/// bytes and touches no reducer with `y_k = 0`, for any fractions `y`.
#[test]
fn partitioner_conserves_bytes_for_any_plan() {
    qcheck(Config::default().cases(80), "partitioner byte conservation", |rng| {
        let r = rng.range(1, 10);
        let mut y: Vec<f64> = (0..r).map(|_| rng.exponential(1.0)).collect();
        if r > 2 {
            // Exercise unused reducers.
            let dead = rng.range(0, r);
            y[dead] = 0.0;
        }
        let total_y: f64 = y.iter().sum();
        for v in y.iter_mut() {
            *v /= total_y;
        }
        let n_buckets = rng.range(r.max(8), 2048);
        let p = Partitioner::from_fractions(&y, n_buckets);

        let records: Vec<Record> = (0..rng.range(1, 600))
            .map(|i| {
                Record::new(
                    format!("key-{i}-{}", rng.next_below(1 << 20)),
                    "v".repeat(rng.range(0, 60)),
                )
            })
            .collect();
        let total = batch_size(&records);
        let mut per_reducer = vec![0usize; r];
        for rec in &records {
            per_reducer[p.reducer(&rec.key)] += rec.size();
        }
        let routed: usize = per_reducer.iter().sum();
        ensure(routed == total, format!("bytes lost: routed {routed} vs {total}"))?;
        for (k, &yk) in y.iter().enumerate() {
            if yk == 0.0 {
                ensure(
                    per_reducer[k] == 0,
                    format!("reducer {k} has y=0 but received {} bytes", per_reducer[k]),
                )?;
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- scheduler

fn check_capacity(
    assignments: &[Assignment],
    free: &[usize],
    label: &str,
) -> Result<(), String> {
    let mut used = vec![0usize; free.len()];
    for a in assignments {
        ensure(a.node < free.len(), format!("{label}: node {} out of range", a.node))?;
        used[a.node] += 1;
    }
    for (n, (&u, &f)) in used.iter().zip(free).enumerate() {
        ensure(u <= f, format!("{label}: node {n} got {u} tasks with {f} free slots"))?;
    }
    Ok(())
}

/// Property: no scheduler implementation ever assigns more tasks to a
/// node than it has free slots — for first placements, stolen work and
/// speculative backups alike.
#[test]
fn schedulers_never_exceed_per_node_capacity() {
    qcheck(Config::default().cases(150), "scheduler slot capacity", |rng| {
        let n_nodes = rng.range(1, 12);
        let n_tasks = rng.range(0, 40);
        let n_clusters = rng.range(1, 4);
        let home: Vec<usize> = (0..n_tasks).map(|_| rng.range(0, n_nodes)).collect();
        let cluster: Vec<usize> = (0..n_nodes).map(|_| rng.range(0, n_clusters)).collect();
        // Down nodes always present zero free slots (executor invariant).
        let up: Vec<bool> = (0..n_nodes).map(|_| rng.chance(0.85)).collect();
        let free: Vec<usize> = (0..n_nodes)
            .map(|n| if up[n] { rng.range(0, 4) } else { 0 })
            .collect();
        let mut queued = vec![0usize; n_nodes];
        for &h in &home {
            queued[h] += 1;
        }
        let capacity: Vec<f64> = (0..n_nodes).map(|_| rng.uniform(1.0, 100.0)).collect();
        let ready: Vec<usize> = (0..n_tasks).filter(|_| rng.chance(0.7)).collect();
        let running: Vec<RunningTask> = (0..n_tasks)
            .filter(|t| !ready.contains(t))
            .map(|t| RunningTask { task: t, node: home[t], started_at: rng.uniform(0.0, 5.0) })
            .collect();
        let durations: Vec<f64> = (0..rng.range(0, 10)).map(|_| rng.uniform(0.1, 1.0)).collect();
        let view = SchedView {
            now: 100.0,
            home: &home,
            ready: &ready,
            running: &running,
            free_slots: &free,
            queued: &queued,
            capacity: &capacity,
            durations: &durations,
            cluster: &cluster,
            up: &up,
        };

        let mut plan_local = PlanLocalScheduler;
        let a = plan_local.assign(&view);
        check_capacity(&a, &free, "plan-local")?;
        for asg in &a {
            ensure(
                asg.node == home[asg.task],
                format!("plan-local placed task {} off its home node", asg.task),
            )?;
        }

        for locality in [false, true] {
            let mut dynamic = DynamicScheduler::new(true, true);
            if locality {
                dynamic = dynamic.with_locality();
            }
            let label = if locality { "dynamic-locality" } else { "dynamic" };
            let a = dynamic.assign(&view);
            check_capacity(&a, &free, &format!("{label} assign"))?;
            let mut seen = std::collections::HashSet::new();
            for asg in &a {
                ensure(!asg.speculative, "assign() must not return speculative placements")?;
                ensure(ready.contains(&asg.task), format!("task {} was not ready", asg.task))?;
                ensure(seen.insert(asg.task), format!("task {} assigned twice", asg.task))?;
                ensure(
                    up[asg.node],
                    format!("{label}: task {} placed on a down node", asg.task),
                )?;
            }
            let backups = dynamic.speculate(&view);
            check_capacity(&backups, &free, &format!("{label} speculate"))?;
            for b in &backups {
                ensure(b.speculative, "speculate() must mark assignments speculative")?;
            }
        }
        Ok(())
    });
}

// ----------------------------------------------------- scale conservation

/// The engine must conserve records on a generated (non-paper) topology,
/// for every generator kind.
#[test]
fn engine_conserves_records_on_generated_topologies() {
    for kind in ScaleKind::all() {
        let topo = generate_kind(kind, 24, 3);
        let plan = Plan::local_push(&topo);
        let inputs = synthetic_inputs(topo.n_sources(), 1 << 14, 0xFEED);
        let total: usize = inputs.iter().map(Vec::len).sum();
        let res = run_job(&topo, &plan, &SyntheticApp::new(1.0), &JobConfig::default(), &inputs);
        assert_eq!(res.metrics.input_records, total, "{kind:?}");
        assert_eq!(res.metrics.output_records, total, "{kind:?}");
        assert!(res.metrics.makespan > 0.0, "{kind:?}");
    }
}

// ------------------------------------------------------------ golden pin

fn metrics_line(kind: EnvKind) -> String {
    let topo = build_env(kind);
    let plan = Plan::uniform(8, 8, 8);
    let inputs = synthetic_inputs(8, 1 << 18, 0x601D);
    let cfg = JobConfig::default();
    let m = run_job(&topo, &plan, &SyntheticApp::new(1.0), &cfg, &inputs).metrics;
    let mut line = String::new();
    write!(
        line,
        "{} makespan={:.6e} push_end={:.6e} map_end={:.6e} shuffle_end={:.6e} \
         push_bytes={:.6e} shuffle_bytes={:.6e} output_bytes={:.6e} \
         map_tasks={} reduce_tasks={} in={} mid={} out={}",
        kind.label(),
        m.makespan,
        m.push_end,
        m.map_end,
        m.shuffle_end,
        m.push_bytes,
        m.shuffle_bytes,
        m.output_bytes,
        m.n_map_tasks,
        m.n_reduce_tasks,
        m.input_records,
        m.intermediate_records,
        m.output_records
    )
    .unwrap();
    line
}

/// Golden pin for the four paper environments (ISSUE 1 acceptance: the
/// refactor is behavior-preserving). The metrics digest is written to
/// tests/golden/env_metrics.txt on first run (bless) and compared
/// byte-for-byte afterwards — the `{:.6e}` rendering gives each float a
/// ~1e-6 relative tolerance. Determinism (two runs identical) is checked
/// unconditionally, so a nondeterministic engine fails even on the
/// blessing run.
#[test]
fn golden_env_metrics_pin_engine_behavior() {
    let mut lines = String::new();
    for kind in EnvKind::all() {
        let first = metrics_line(kind);
        let second = metrics_line(kind);
        assert_eq!(first, second, "{kind:?}: engine run is nondeterministic");
        lines.push_str(&first);
        lines.push('\n');
    }

    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/env_metrics.txt");
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            assert_eq!(
                lines,
                golden,
                "engine metrics diverged from the golden pin at {} — if the \
                 change is intentional, delete the file and rerun to re-bless",
                path.display()
            );
        }
        Err(_) => {
            // First run (or fresh checkout): bless the current metrics.
            // The write is best-effort so a read-only checkout still runs
            // the determinism assertions above; the file should be
            // committed once generated so later PRs inherit a real pin.
            let blessed = std::fs::create_dir_all(path.parent().unwrap())
                .and_then(|_| std::fs::write(&path, &lines));
            match blessed {
                Ok(()) => eprintln!("blessed new golden file {}", path.display()),
                Err(e) => eprintln!(
                    "could not bless golden file {} ({e}); determinism was still checked",
                    path.display()
                ),
            }
        }
    }
}
