"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

The kernel must reproduce ``ref.plan_eval_ref`` exactly (same ops, same
dtype) across shapes, barrier configurations and parameter ranges —
hypothesis drives the sweep. This is the core correctness signal for the
compute hot-spot that ships inside the AOT artifacts.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.makespan_kernel import plan_eval, plan_eval_padded
from compile.kernels.ref import plan_eval_ref

# Barrier selector vectors: (pm_g, pm_p, ms_g, ms_p, sr_g, sr_p).
SEL_GGG = [1, 0, 1, 0, 1, 0]
SEL_HADOOP = [1, 0, 0, 1, 0, 0]  # G-P-L
SEL_PPP = [0, 1, 0, 1, 0, 1]
SEL_LLL = [0, 0, 0, 0, 0, 0]
ALL_SELS = [SEL_GGG, SEL_HADOOP, SEL_PPP, SEL_LLL]


def make_instance(rng, P, S, M, R):
    """Random valid instance (plans on the simplex, positive rates)."""
    x = rng.gamma(1.0, size=(P, S, M)).astype(np.float32) + 1e-3
    x /= x.sum(axis=2, keepdims=True)
    y = rng.gamma(1.0, size=(P, R)).astype(np.float32) + 1e-3
    y /= y.sum(axis=1, keepdims=True)
    d = rng.uniform(0.5, 4.0, size=(S,)).astype(np.float32)
    b_sm = rng.uniform(0.05, 2.0, size=(S, M)).astype(np.float32)
    b_mr = rng.uniform(0.05, 2.0, size=(M, R)).astype(np.float32)
    c_map = rng.uniform(0.2, 2.0, size=(M,)).astype(np.float32)
    c_red = rng.uniform(0.2, 2.0, size=(R,)).astype(np.float32)
    return x, y, d, b_sm, b_mr, c_map, c_red


@pytest.mark.parametrize("sel", ALL_SELS, ids=["GGG", "GPL", "PPP", "LLL"])
@pytest.mark.parametrize("alpha", [0.1, 1.0, 10.0])
def test_kernel_matches_ref_8x8x8(sel, alpha):
    rng = np.random.default_rng(42)
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 16, 8, 8, 8)
    sel_arr = jnp.asarray(sel, dtype=jnp.float32)
    got = plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel_arr)
    want = plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel_arr)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_single_block():
    rng = np.random.default_rng(0)
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 8, 3, 4, 5)
    sel = jnp.asarray(SEL_GGG, dtype=jnp.float32)
    got = plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, 1.0, sel)
    want = plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, 1.0, sel)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_padded_wrapper_handles_ragged_batches():
    rng = np.random.default_rng(1)
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 11, 2, 2, 2)
    sel = jnp.asarray(SEL_HADOOP, dtype=jnp.float32)
    got = plan_eval_padded(x, y, d, b_sm, b_mr, c_map, c_red, 2.0, sel)
    want = plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, 2.0, sel)
    assert got.shape == (11, 5)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_segments_sum_to_makespan():
    rng = np.random.default_rng(2)
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 8, 4, 4, 4)
    for sel in ALL_SELS:
        sel_arr = jnp.asarray(sel, dtype=jnp.float32)
        out = np.asarray(
            plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, 1.5, sel_arr)
        )
        np.testing.assert_allclose(out[:, :4].sum(axis=1), out[:, 4], rtol=1e-5)
        assert (out >= -1e-6).all()


def test_known_small_instance():
    # §1.3 scenario 1 analog: homogeneous, uniform plan. D=150/50 GB,
    # B=C=0.1 GBps -> push 750 s, map 1000 s, shuffle 500 s, reduce 1000 s.
    x = jnp.full((1, 2, 2), 0.5, dtype=jnp.float32)
    y = jnp.full((1, 2), 0.5, dtype=jnp.float32)
    d = jnp.asarray([150.0, 50.0], dtype=jnp.float32)
    b = jnp.full((2, 2), 0.1, dtype=jnp.float32)
    c = jnp.full((2,), 0.1, dtype=jnp.float32)
    sel = jnp.asarray(SEL_GGG, dtype=jnp.float32)
    out = np.asarray(plan_eval(x, y, d, b, b, c, c, 1.0, sel, block=1))
    np.testing.assert_allclose(out[0], [750.0, 1000.0, 500.0, 1000.0, 3250.0], rtol=1e-5)


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(1, 6),
    m=st.integers(1, 6),
    r=st.integers(1, 6),
    alpha=st.floats(0.0, 12.0),
    sel_idx=st.integers(0, len(ALL_SELS) - 1),
)
def test_kernel_matches_ref_hypothesis(seed, s, m, r, alpha, sel_idx):
    """Shape/parameter sweep: kernel == oracle everywhere."""
    rng = np.random.default_rng(seed)
    P = 8
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, P, s, m, r)
    sel = jnp.asarray(ALL_SELS[sel_idx], dtype=jnp.float32)
    got = plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel)
    want = plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_uniform_plan_dominated_by_no_plan_being_negative(seed):
    """Sanity invariants under random parameters: non-negative times,
    alpha=0 collapses shuffle+reduce."""
    rng = np.random.default_rng(seed)
    x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 8, 3, 3, 3)
    sel = jnp.asarray(SEL_GGG, dtype=jnp.float32)
    out = np.asarray(plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, 0.0, sel))
    np.testing.assert_allclose(out[:, 2], 0.0, atol=1e-6)  # shuffle
    np.testing.assert_allclose(out[:, 3], 0.0, atol=1e-6)  # reduce
    assert (out[:, 4] > 0).all()


def test_dtype_f64_supported():
    # The oracle and kernel agree in float64 too (x64 path used by the
    # validation notebooks; artifacts stay f32).
    jax.config.update("jax_enable_x64", True)
    try:
        rng = np.random.default_rng(3)
        x, y, d, b_sm, b_mr, c_map, c_red = make_instance(rng, 8, 2, 3, 2)
        to64 = lambda a: jnp.asarray(a, dtype=jnp.float64)
        args = tuple(map(to64, (x, y, d, b_sm, b_mr, c_map, c_red)))
        sel = jnp.asarray(SEL_PPP, dtype=jnp.float64)
        got = plan_eval(*args, 1.0, sel)
        want = plan_eval_ref(*args, 1.0, sel)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)
