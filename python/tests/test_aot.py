"""AOT export smoke: lowering succeeds, HLO text is parseable-looking,
and the manifest covers every artifact."""

import json
import pathlib

from compile.aot import SHAPES, lower_opt_run, lower_plan_eval, to_hlo_text


def test_lowering_produces_hlo_text():
    lowered = lower_plan_eval(2, 2, 2, 4)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Pallas (interpret) lowers to plain HLO: no Mosaic custom-calls,
    # which the CPU PJRT plugin could not execute.
    assert "mosaic" not in text.lower()


def test_opt_run_lowering_contains_loop():
    text = to_hlo_text(lower_opt_run(2, 2, 2, 4))
    assert text.startswith("HloModule")
    assert "while" in text, "fori_loop should lower to an HLO while"


def test_shapes_cover_paper_scale_and_mini():
    dims = {(s["S"], s["M"], s["R"]) for s in SHAPES}
    assert (8, 8, 8) in dims, "paper-scale artifact required"
    assert any(s["S"] <= 2 for s in SHAPES), "mini artifact for fast tests"


def test_manifest_consistent_if_built():
    out = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    man = out / "manifest.json"
    if not man.exists():
        return  # `make artifacts` not run yet; covered by Makefile flow
    entries = json.loads(man.read_text())
    for name, meta in entries.items():
        path = out / meta["file"]
        assert path.exists(), f"missing artifact {name}"
        head = path.read_text()[:200]
        assert head.startswith("HloModule"), f"{name} is not HLO text"
