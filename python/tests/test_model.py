"""L2 correctness: smooth model, gradients and the opt_run loop."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import plan_eval_ref, smooth_makespan_ref
from compile.model import init_state, opt_run, plan_eval_hard

SEL_GGG = jnp.asarray([1, 0, 1, 0, 1, 0], dtype=jnp.float32)


def platform_1_3(nonlocal_b=0.01):
    """The paper's §1.3 two-cluster example in GB/GBps units."""
    d = jnp.asarray([150.0, 50.0], dtype=jnp.float32)
    b = jnp.asarray([[0.1, nonlocal_b], [nonlocal_b, 0.1]], dtype=jnp.float32)
    c = jnp.asarray([0.1, 0.1], dtype=jnp.float32)
    return d, b, b, c, c


def test_smooth_upper_bounds_hard():
    rng = np.random.default_rng(5)
    P, S, M, R = 8, 2, 2, 2
    lx = jnp.asarray(rng.normal(size=(P, S, M)), dtype=jnp.float32)
    ly = jnp.asarray(rng.normal(size=(P, R)), dtype=jnp.float32)
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    hard = plan_eval_hard(lx, ly, d, b_sm, b_mr, c_map, c_red, 1.0, SEL_GGG)[:, 4]
    for beta_scale in (0.01, 0.1):
        soft = smooth_makespan_ref(
            lx, ly, d, b_sm, b_mr, c_map, c_red, 1.0, SEL_GGG, beta_scale
        )
        assert (np.asarray(soft) >= np.asarray(hard) - 1e-3).all()
    # Sharper beta → tighter bound.
    s1 = smooth_makespan_ref(lx, ly, d, b_sm, b_mr, c_map, c_red, 1.0, SEL_GGG, 0.01)
    s2 = smooth_makespan_ref(lx, ly, d, b_sm, b_mr, c_map, c_red, 1.0, SEL_GGG, 0.1)
    assert (np.asarray(s2) <= np.asarray(s1) + 1e-4).all()


def test_gradients_finite_and_descend():
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    lx = jnp.zeros((4, 2, 2), dtype=jnp.float32)
    ly = jnp.zeros((4, 2), dtype=jnp.float32)
    beta = jnp.float32(0.01)

    def loss(lx, ly):
        return smooth_makespan_ref(
            lx, ly, d, b_sm, b_mr, c_map, c_red, 1.0, SEL_GGG, beta
        ).sum()

    g = jax.grad(loss, argnums=(0, 1))(lx, ly)
    assert np.isfinite(np.asarray(g[0])).all()
    assert np.isfinite(np.asarray(g[1])).all()
    # A small step against the gradient lowers the loss.
    l0 = loss(lx, ly)
    l1 = loss(lx - 0.5 * g[0], ly - 0.5 * g[1])
    assert l1 < l0


def test_opt_run_improves_over_uniform():
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    P, S, M, R = 4, 2, 2, 2
    state = init_state(jax.random.PRNGKey(0), P, S, M, R)
    lx, ly, mx, vx, my, vy, t = state
    alpha = jnp.float32(10.0)
    sel = SEL_GGG
    uniform_ms = float(
        plan_eval_hard(jnp.zeros((1, S, M)), jnp.zeros((1, R)),
                       d, b_sm, b_mr, c_map, c_red, alpha, sel)[0, 4]
    )
    gscale = jnp.float32(uniform_ms)
    # Anneal beta over several opt_run calls (as the rust driver does).
    for beta_norm in (20.0, 60.0, 200.0):
        beta = jnp.float32(beta_norm / uniform_ms)
        lx, ly, mx, vx, my, vy, t, _ = opt_run(
            lx, ly, mx, vx, my, vy, t, beta, jnp.float32(0.25),
            d, b_sm, b_mr, c_map, c_red, alpha, sel, gscale,
        )
    final = plan_eval_hard(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel)
    best = float(np.asarray(final[:, 4]).min())
    assert best < 0.75 * uniform_ms, f"best {best} vs uniform {uniform_ms}"


def test_opt_run_preserves_shapes_and_advances_t():
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    state = init_state(jax.random.PRNGKey(1), 4, 2, 2, 2)
    lx, ly, mx, vx, my, vy, t = state
    out = opt_run(
        lx, ly, mx, vx, my, vy, t, jnp.float32(0.01), jnp.float32(0.1),
        d, b_sm, b_mr, c_map, c_red, jnp.float32(1.0), SEL_GGG, jnp.float32(1000.0),
    )
    assert out[0].shape == (4, 2, 2)
    assert out[1].shape == (4, 2)
    assert float(out[6]) == 20.0  # K_STEPS
    assert out[7].shape == (4,)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(0.05, 10.0))
def test_softmax_plans_always_valid(seed, alpha):
    """Any logits decode to a valid plan: rows sum to 1, entries in
    [0,1]; the evaluation is finite."""
    rng = np.random.default_rng(seed)
    lx = jnp.asarray(rng.normal(scale=3.0, size=(4, 3, 3)), dtype=jnp.float32)
    ly = jnp.asarray(rng.normal(scale=3.0, size=(4, 3)), dtype=jnp.float32)
    x = jax.nn.softmax(lx, axis=2)
    np.testing.assert_allclose(np.asarray(x.sum(axis=2)), 1.0, rtol=1e-5)
    d = jnp.asarray(rng.uniform(0.5, 2.0, size=(3,)), dtype=jnp.float32)
    b = jnp.asarray(rng.uniform(0.05, 1.0, size=(3, 3)), dtype=jnp.float32)
    c = jnp.asarray(rng.uniform(0.2, 1.0, size=(3,)), dtype=jnp.float32)
    out = plan_eval_hard(lx, ly, d, b, b, c, c, jnp.float32(alpha), SEL_GGG)
    assert np.isfinite(np.asarray(out)).all()


def test_consolidation_insight_alpha10():
    """§1.3, α=10: the optimizer should discover the consolidation plan
    (all data to cluster 1) and beat uniform by a wide margin."""
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    alpha = jnp.float32(10.0)
    # Hand-built narrative plan: everything to mapper 0 / reducer 0.
    lx = jnp.zeros((1, 2, 2)).at[:, :, 0].set(8.0)
    ly = jnp.zeros((1, 2)).at[:, 0].set(8.0)
    narrative = float(
        plan_eval_hard(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, SEL_GGG)[0, 4]
    )
    uniform = float(
        plan_eval_hard(jnp.zeros((1, 2, 2)), jnp.zeros((1, 2)),
                       d, b_sm, b_mr, c_map, c_red, alpha, SEL_GGG)[0, 4]
    )
    # Consolidation avoids the non-local heavy shuffle: 47,000 s vs
    # 68,500 s for uniform on this instance (exact closed-form values).
    assert narrative < 0.75 * uniform


def test_ref_matches_paper_1_3_numbers():
    d, b_sm, b_mr, c_map, c_red = platform_1_3()
    # Local push plan, α=1: push phase = 1500 s (§1.3).
    x = jnp.asarray([[[1.0, 0.0]], [[0.0, 1.0]]], dtype=jnp.float32).reshape(1, 2, 2)
    y = jnp.full((1, 2), 0.5, dtype=jnp.float32)
    out = np.asarray(
        plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, 1.0,
                      jnp.asarray([1, 0, 1, 0, 1, 0], dtype=jnp.float32))
    )
    np.testing.assert_allclose(out[0, 0], 1500.0, rtol=1e-5)
