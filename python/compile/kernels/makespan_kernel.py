"""L1: batched plan-evaluation Pallas kernel.

The coordinator's hot compute is scoring *batches of candidate execution
plans* under the makespan model (multi-start selection, what-if sweeps).
This kernel evaluates a block of plans entirely inside one VMEM-resident
tile: the (BP, S, M) plan block, the platform tensors and every
intermediate phase tensor stay on-chip; only the (BP, 5) result leaves.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the batch
dimension; ``BlockSpec((BP, S, M), lambda p: (p, 0, 0))`` expresses the
HBM→VMEM schedule. At S = M = R = 8 and BP = 256 the working set is
~350 KiB — comfortably inside one core's 16 MiB VMEM; the arithmetic is
elementwise + small reductions (VPU work; the MXU is idle, the kernel is
bandwidth-bound — see EXPERIMENTS.md §Perf for the roofline argument).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO which both the pytest
suite and the rust runtime run bit-compatibly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch-block size (plans evaluated per grid step).
DEFAULT_BLOCK = 8


def _kernel(x_ref, y_ref, d_ref, bsm_ref, bmr_ref, cmap_ref, cred_ref,
            alpha_ref, sel_ref, out_ref):
    """One grid step: evaluate BP plans held in VMEM."""
    x = x_ref[...]            # (BP, S, M)
    y = y_ref[...]            # (BP, R)
    d = d_ref[...]            # (S,)
    b_sm = bsm_ref[...]       # (S, M)
    b_mr = bmr_ref[...]       # (M, R)
    c_map = cmap_ref[...]     # (M,)
    c_red = cred_ref[...]     # (R,)
    alpha = alpha_ref[0]
    sel = sel_ref[...]        # (6,)
    pm_g, pm_p, ms_g, ms_p, sr_g, sr_p = (sel[i] for i in range(6))

    def combine(start, cost, g, p, phase_max):
        base = g * phase_max + (1.0 - g) * start
        return p * jnp.maximum(base, cost) + (1.0 - p) * (base + cost)

    # push (eq 4)
    push_t = d[None, :, None] * x / b_sm[None, :, :]
    push_end = jnp.max(push_t, axis=1)                      # (BP, M)
    push_max = jnp.max(push_end, axis=1, keepdims=True)     # (BP, 1)

    # map (eqs 5/6/12)
    loads = jnp.sum(d[None, :, None] * x, axis=1)           # (BP, M)
    map_end = combine(push_end, loads / c_map[None, :], pm_g, pm_p, push_max)
    map_max = jnp.max(map_end, axis=1, keepdims=True)

    # shuffle (eqs 7/8/13)
    vol = alpha * loads[:, :, None] * y[:, None, :]         # (BP, M, R)
    sh_per_j = combine(
        map_end[:, :, None], vol / b_mr[None, :, :], ms_g, ms_p,
        map_max[:, :, None],
    )
    shuffle_end = jnp.max(sh_per_j, axis=1)                 # (BP, R)
    shuffle_max = jnp.max(shuffle_end, axis=1, keepdims=True)

    # reduce (eqs 9/10/14)
    d_total = jnp.sum(d)
    red_cost = alpha * d_total * y / c_red[None, :]
    reduce_end = combine(shuffle_end, red_cost, sr_g, sr_p, shuffle_max)
    makespan = jnp.max(reduce_end, axis=1)                  # (BP,)

    p_end = push_max[:, 0]
    m_end = map_max[:, 0]
    s_end = shuffle_max[:, 0]
    out_ref[...] = jnp.stack(
        [
            p_end,
            jnp.maximum(m_end - p_end, 0.0),
            jnp.maximum(s_end - m_end, 0.0),
            jnp.maximum(makespan - s_end, 0.0),
            makespan,
        ],
        axis=1,
    )


@functools.partial(jax.jit, static_argnames=("block",))
def plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel, *, block=DEFAULT_BLOCK):
    """Evaluate a batch of plans; returns (P, 5) phase segments+makespan.

    ``P`` must be a multiple of ``block`` (the AOT exporter picks matching
    sizes; tests exercise ragged cases through the padding helper).
    """
    P, S, M = x.shape
    R = y.shape[1]
    assert y.shape[0] == P
    assert P % block == 0, f"batch {P} not a multiple of block {block}"
    alpha_arr = jnp.asarray(alpha, dtype=x.dtype).reshape((1,))
    grid = (P // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, S, M), lambda p: (p, 0, 0)),
            pl.BlockSpec((block, R), lambda p: (p, 0)),
            pl.BlockSpec((S,), lambda p: (0,)),
            pl.BlockSpec((S, M), lambda p: (0, 0)),
            pl.BlockSpec((M, R), lambda p: (0, 0)),
            pl.BlockSpec((M,), lambda p: (0,)),
            pl.BlockSpec((R,), lambda p: (0,)),
            pl.BlockSpec((1,), lambda p: (0,)),
            pl.BlockSpec((6,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec((block, 5), lambda p: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 5), x.dtype),
        interpret=True,
    )(x, y, d, b_sm, b_mr, c_map, c_red, alpha_arr, sel)


def plan_eval_padded(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel,
                     block=DEFAULT_BLOCK):
    """Ragged-batch wrapper: pads P up to a block multiple and trims."""
    P = x.shape[0]
    pad = (-P) % block
    if pad:
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
        y = jnp.concatenate([y, jnp.repeat(y[-1:], pad, axis=0)], axis=0)
    out = plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel, block=block)
    return out[:P]
