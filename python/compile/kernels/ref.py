"""Pure-jnp reference oracle for the batched plan-evaluation kernel.

This mirrors the rust exact evaluator (``rust/src/model/makespan.rs``,
eqs 4-14 of the paper) over a *batch* of plans. The Pallas kernel in
``makespan_kernel.py`` must agree with this to float tolerance — that is
the L1 correctness contract, enforced by ``python/tests/test_kernel.py``
(including hypothesis sweeps over shapes and parameters).

Conventions (shared with the rust side and the AOT artifacts):

* ``x``: (P, S, M) — push fractions, rows on the simplex.
* ``y``: (P, R) — key-space fractions.
* ``d``: (S,) bytes; ``b_sm``: (S, M); ``b_mr``: (M, R) bytes/s;
  ``c_map``: (M,); ``c_red``: (R,) bytes/s.
* ``sel``: (6,) barrier selectors (pm_g, pm_p, ms_g, ms_p, sr_g, sr_p),
  1.0/0.0 floats — Global sets ``*_g``; Pipelined sets ``*_p``; Local
  sets neither (see rust ``model::smooth::selectors``).

Output: (P, 5) — [push, map, shuffle, reduce, makespan] where the first
four are the marginal critical-path phase durations (the stacked-bar
decomposition used in Figs 5/6/9) and column 4 is the makespan (eq 11).
"""

import jax
import jax.numpy as jnp


def combine(start, cost, g, p, phase_max):
    """The paper's ⊕ with barrier selectors.

    start: per-node previous end; phase_max: global max of previous ends.
    Global: phase_max + cost; Local: start + cost; Pipelined:
    max(start, cost).
    """
    base = g * phase_max + (1.0 - g) * start
    return p * jnp.maximum(base, cost) + (1.0 - p) * (base + cost)


def plan_eval_ref(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel):
    """Batched exact makespan evaluation (hard max), eqs 4-14."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    pm_g, pm_p, ms_g, ms_p, sr_g, sr_p = (sel[i] for i in range(6))

    # push (eq 4): (P, S, M) -> (P, M)
    push_t = d[None, :, None] * x / b_sm[None, :, :]
    push_end = jnp.max(push_t, axis=1)
    push_max = jnp.max(push_end, axis=1, keepdims=True)  # (P, 1)

    # map (eqs 5/6/12)
    loads = jnp.sum(d[None, :, None] * x, axis=1)  # (P, M)
    map_cost = loads / c_map[None, :]
    map_end = combine(push_end, map_cost, pm_g, pm_p, push_max)
    map_max = jnp.max(map_end, axis=1, keepdims=True)

    # shuffle (eqs 7/8/13): vol (P, M, R)
    vol = alpha * loads[:, :, None] * y[:, None, :]
    sh_t = vol / b_mr[None, :, :]
    sh_per_j = combine(map_end[:, :, None], sh_t, ms_g, ms_p, map_max[:, :, None])
    shuffle_end = jnp.max(sh_per_j, axis=1)  # (P, R)
    shuffle_max = jnp.max(shuffle_end, axis=1, keepdims=True)

    # reduce (eqs 9/10/14)
    d_total = jnp.sum(d)
    red_cost = alpha * d_total * y / c_red[None, :]
    reduce_end = combine(shuffle_end, red_cost, sr_g, sr_p, shuffle_max)
    makespan = jnp.max(reduce_end, axis=1)  # (P,)

    # Stacked-bar decomposition (clamped marginal contributions).
    p_end = push_max[:, 0]
    m_end = map_max[:, 0]
    s_end = shuffle_max[:, 0]
    push_seg = p_end
    map_seg = jnp.maximum(m_end - p_end, 0.0)
    shuffle_seg = jnp.maximum(s_end - m_end, 0.0)
    reduce_seg = jnp.maximum(makespan - s_end, 0.0)
    return jnp.stack([push_seg, map_seg, shuffle_seg, reduce_seg, makespan], axis=1)


def smooth_makespan_ref(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel, beta):
    """Batched *smooth* makespan from logits — rust ``model::smooth`` twin.

    lx: (P, S, M) logits; ly: (P, R) logits. Returns (P,) smooth makespan.
    """
    x = jax.nn.softmax(lx, axis=2)
    y = jax.nn.softmax(ly, axis=1)
    pm_g, pm_p, ms_g, ms_p, sr_g, sr_p = (sel[i] for i in range(6))

    def smax(v, axis):
        return jax.nn.logsumexp(beta * v, axis=axis) / beta

    def scombine(start, cost, g, p, phase_max):
        base = g * phase_max + (1.0 - g) * start
        pipe = jnp.logaddexp(beta * base, beta * cost) / beta
        return p * pipe + (1.0 - p) * (base + cost)

    push_t = d[None, :, None] * x / b_sm[None, :, :]
    push_end = smax(push_t, axis=1)  # (P, M)
    push_max = smax(push_end, axis=1)[:, None]

    loads = jnp.sum(d[None, :, None] * x, axis=1)
    map_cost = loads / c_map[None, :]
    map_end = scombine(push_end, map_cost, pm_g, pm_p, push_max)
    map_max = smax(map_end, axis=1)[:, None]

    vol = alpha * loads[:, :, None] * y[:, None, :]
    sh_t = vol / b_mr[None, :, :]
    sh_per_j = scombine(map_end[:, :, None], sh_t, ms_g, ms_p, map_max[:, :, None])
    shuffle_end = smax(sh_per_j, axis=1)
    shuffle_max = smax(shuffle_end, axis=1)[:, None]

    d_total = jnp.sum(d)
    red_cost = alpha * d_total * y / c_red[None, :]
    reduce_end = scombine(shuffle_end, red_cost, sr_g, sr_p, shuffle_max)
    return smax(reduce_end, axis=1)
