"""L2: the differentiable plan-optimization model.

The end-to-end multi-phase optimization (§2.3 of the paper) solved by
gradient descent on a smooth relaxation of the makespan model: plans are
parameterized by logits (row-softmax → x, softmax → y, so eqs 1-3 hold by
construction); every hard ``max`` is ``logsumexp(β·)/β``; β anneals from
soft to hard across calls. A batch of P multi-starts advances in lock-
step so one device call moves the whole optimization.

Two jitted entry points are AOT-lowered by ``aot.py`` and executed from
the rust coordinator via PJRT:

* ``opt_run`` — K Adam steps on the batched logits (lax.fori_loop inside
  one executable, so the rust side pays one PJRT dispatch per K steps).
* ``plan_eval_hard`` — exact (hard-max) batched evaluation through the
  L1 Pallas kernel, used to score candidates and pick the winner.

The rust twin of the smooth model is ``rust/src/model/smooth.rs``;
parity is pinned by tests on both sides.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.makespan_kernel import plan_eval
from .kernels.ref import smooth_makespan_ref

# Adam steps fused into one opt_run call.
K_STEPS = 20
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def loss_fn(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel, beta, gscale):
    """Mean scaled smooth makespan over the batch (scalar)."""
    ms = smooth_makespan_ref(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel, beta)
    return jnp.sum(ms / gscale), ms


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5))
def opt_run(lx, ly, mx, vx, my, vy, t0, beta, lr,
            d, b_sm, b_mr, c_map, c_red, alpha, sel, gscale):
    """K_STEPS of batched Adam on the smooth makespan.

    Returns (lx, ly, mx, vx, my, vy, t, loss) with ``loss`` the per-plan
    smooth makespan (seconds) after the last step. Buffers are donated —
    the rust caller feeds each call's outputs into the next.
    """

    grad_fn = jax.grad(
        lambda lx_, ly_: loss_fn(
            lx_, ly_, d, b_sm, b_mr, c_map, c_red, alpha, sel, beta, gscale
        )[0],
        argnums=(0, 1),
    )

    def adam(m, v, g, t):
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mh = m / (1.0 - ADAM_B1 ** t)
        vh = v / (1.0 - ADAM_B2 ** t)
        return m, v, mh / (jnp.sqrt(vh) + ADAM_EPS)

    def body(_, state):
        lx, ly, mx, vx, my, vy, t = state
        gx, gy = grad_fn(lx, ly)
        t = t + 1.0
        mx, vx, ux = adam(mx, vx, gx, t)
        my, vy, uy = adam(my, vy, gy, t)
        return (lx - lr * ux, ly - lr * uy, mx, vx, my, vy, t)

    lx, ly, mx, vx, my, vy, t = jax.lax.fori_loop(
        0, K_STEPS, body, (lx, ly, mx, vx, my, vy, t0)
    )
    _, ms = loss_fn(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel, beta, gscale)
    return lx, ly, mx, vx, my, vy, t, ms


@jax.jit
def plan_eval_hard(lx, ly, d, b_sm, b_mr, c_map, c_red, alpha, sel):
    """Exact evaluation of the plans the logits encode, via the L1 kernel.

    Returns (P, 5): phase segments + makespan (hard max, eqs 4-14).
    """
    import math

    from .kernels.makespan_kernel import DEFAULT_BLOCK

    x = jax.nn.softmax(lx, axis=2)
    y = jax.nn.softmax(ly, axis=1)
    block = math.gcd(lx.shape[0], DEFAULT_BLOCK)
    return plan_eval(x, y, d, b_sm, b_mr, c_map, c_red, alpha, sel, block=block)


def init_state(key, P, S, M, R, init_scale=0.5):
    """Fresh multi-start state: start 0 is the uniform plan (zero logits),
    the rest are gaussian perturbations."""
    kx, ky = jax.random.split(key)
    lx = init_scale * jax.random.normal(kx, (P, S, M), dtype=jnp.float32)
    ly = init_scale * jax.random.normal(ky, (P, R), dtype=jnp.float32)
    lx = lx.at[0].set(0.0)
    ly = ly.at[0].set(0.0)
    # Distinct zero buffers: opt_run donates its arguments, and donating
    # one buffer twice is an error.
    return (
        lx,
        ly,
        jnp.zeros_like(lx),
        jnp.zeros_like(lx),
        jnp.zeros_like(ly),
        jnp.zeros_like(ly),
        jnp.float32(0.0),
    )
