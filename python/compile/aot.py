"""AOT export: lower the L2/L1 graphs to HLO *text* artifacts.

HLO text — NOT ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shape-specialized; the rust runtime picks by filename):

* ``opt_run_s{S}m{M}r{R}p{P}.hlo.txt``    — K Adam steps on P starts.
* ``plan_eval_s{S}m{M}r{R}p{P}.hlo.txt``  — batched hard evaluation
  through the L1 Pallas kernel.

Run via ``make artifacts`` (no-op when inputs are unchanged). Python
never runs after this point; the rust binary is self-contained.
"""

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import opt_run, plan_eval_hard

# Exported shape set: the paper-scale 8×8×8 environment with 16 starts,
# plus a miniature for fast rust-side integration tests.
SHAPES = [
    {"S": 8, "M": 8, "R": 8, "P": 16},
    {"S": 2, "M": 2, "R": 2, "P": 4},
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_opt_run(S, M, R, P):
    return jax.jit(opt_run).lower(
        f32(P, S, M), f32(P, R),              # lx, ly
        f32(P, S, M), f32(P, S, M),           # mx, vx
        f32(P, R), f32(P, R),                 # my, vy
        f32(), f32(), f32(),                  # t0, beta, lr
        f32(S), f32(S, M), f32(M, R),         # d, b_sm, b_mr
        f32(M), f32(R),                       # c_map, c_red
        f32(), f32(6),                        # alpha, sel
        f32(),                                # gscale
    )


def lower_plan_eval(S, M, R, P):
    return jax.jit(plan_eval_hard).lower(
        f32(P, S, M), f32(P, R),
        f32(S), f32(S, M), f32(M, R), f32(M), f32(R),
        f32(), f32(6),
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {}
    for sh in SHAPES:
        S, M, R, P = sh["S"], sh["M"], sh["R"], sh["P"]
        tag = f"s{S}m{M}r{R}p{P}"

        for name, lower in (("opt_run", lower_opt_run), ("plan_eval", lower_plan_eval)):
            text = to_hlo_text(lower(S, M, R, P))
            path = out_dir / f"{name}_{tag}.hlo.txt"
            path.write_text(text)
            manifest[f"{name}_{tag}"] = {
                "file": path.name, "S": S, "M": M, "R": R, "P": P,
                "k_steps": 20 if name == "opt_run" else None,
            }
            print(f"wrote {path} ({len(text)} chars)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
