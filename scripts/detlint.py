#!/usr/bin/env python3
"""detlint (Python mirror) — determinism & invariant static analysis.

Behavioral mirror of the canonical Rust implementation in
rust/tools/detlint. It exists so the detlint gate runs in CI and
builder containers that carry **no Rust toolchain**: the pass is pure
source analysis, so requiring cargo to enforce it would be
self-defeating. Both implementations are pinned to the same findings
over rust/tools/detlint/tests/fixtures (see --self-test), and the rule
catalog is documented once in docs/LINTS.md.

Usage:
    scripts/detlint.py [--json] [PATH ...]    # default PATH: rust/src
    scripts/detlint.py --self-test            # fixture + JSON contract

Exit codes: 0 clean, 1 findings, 2 usage/IO errors.
"""

import json
import os
import sys

RULE_IDS = ("D001", "D002", "D003", "D004", "D005", "D006")
D001_SORT_WINDOW = 8
D006_COMMENT_WINDOW = 3
D001_METHODS = (
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".drain(",
)
D002_OPENERS = ("sort_by", "sort_unstable_by", "max_by", "min_by", "binary_search_by")
D006_SUFFIXES = ("_bytes_delivered", "_repushed", "_replayed")


def is_word(c):
    return c.isalnum() and c.isascii() or c == "_"


def mask_source(text):
    """Split source into (code_lines, comment_lines) with string/char
    literal contents and comments blanked out of the code stream."""
    CODE, LINE, BLOCK, STR, RAWSTR, CHR = range(6)
    chars = text
    n = len(chars)
    code, com = [], []
    st, depth, hashes = CODE, 0, 0
    i = 0

    def blank(k):
        code.append(" " * k)
        com.append(" " * k)

    while i < n:
        c = chars[i]
        if c == "\n":
            code.append("\n")
            com.append("\n")
            if st == LINE:
                st = CODE
            i += 1
            continue
        nxt = chars[i + 1] if i + 1 < n else ""
        if st == CODE:
            prev_word = i > 0 and is_word(chars[i - 1])
            if c == "/" and nxt == "/":
                st = LINE
                code.append("  ")
                com.append("//")
                i += 2
            elif c == "/" and nxt == "*":
                st, depth = BLOCK, 1
                code.append("  ")
                com.append("/*")
                i += 2
            elif c == '"':
                st = STR
                blank(1)
                i += 1
            elif c in ("r", "b") and not prev_word:
                j = i + 1 if c == "b" else i
                is_b = c == "b"
                if is_b and j < n and chars[j] == "'":
                    blank(2)
                    st = CHR
                    i = j + 1
                    continue
                if is_b and j < n and chars[j] == '"':
                    blank(2)
                    st = STR
                    i = j + 1
                    continue
                if is_b and (j >= n or chars[j] != "r"):
                    code.append(c)
                    com.append(" ")
                    i += 1
                    continue
                j = j + 1 if is_b else i + 1
                h = 0
                while j < n and chars[j] == "#":
                    h += 1
                    j += 1
                if j < n and chars[j] == '"':
                    blank(j + 1 - i)
                    st, hashes = RAWSTR, h
                    i = j + 1
                else:
                    code.append(c)
                    com.append(" ")
                    i += 1
            elif c == "'":
                if nxt == "\\":
                    blank(1)
                    st = CHR
                    i += 1
                elif i + 2 < n and chars[i + 2] == "'" and nxt != "'":
                    blank(3)
                    i += 3
                else:
                    code.append("'")
                    com.append(" ")
                    i += 1
            else:
                code.append(c)
                com.append(" ")
                i += 1
        elif st == LINE:
            com.append(c)
            code.append(" ")
            i += 1
        elif st == BLOCK:
            if c == "/" and nxt == "*":
                depth += 1
                com.append("/*")
                code.append("  ")
                i += 2
            elif c == "*" and nxt == "/":
                depth -= 1
                st = CODE if depth == 0 else BLOCK
                com.append("*/")
                code.append("  ")
                i += 2
            else:
                com.append(c)
                code.append(" ")
                i += 1
        elif st == STR:
            if c == "\\" and nxt and nxt != "\n":
                blank(2)
                i += 2
            elif c == '"':
                st = CODE
                blank(1)
                i += 1
            else:
                blank(1)
                i += 1
        elif st == RAWSTR:
            if c == '"' and chars[i + 1 : i + 1 + hashes] == "#" * hashes:
                blank(1 + hashes)
                st = CODE
                i += 1 + hashes
            else:
                blank(1)
                i += 1
        else:  # CHR
            if c == "\\" and nxt and nxt != "\n":
                blank(2)
                i += 2
            elif c == "'":
                st = CODE
                blank(1)
                i += 1
            else:
                blank(1)
                i += 1
    joined_code = "".join(code).split("\n")
    joined_com = "".join(com).split("\n")
    return joined_code, joined_com


def token_positions(hay, needle):
    """Word-bounded occurrences (boundaries enforced only on word-char
    needle edges, so `.spawn(` and `std::time` work)."""
    out = []
    if not needle or len(hay) < len(needle):
        return out
    first_w, last_w = is_word(needle[0]), is_word(needle[-1])
    start = 0
    while True:
        p = hay.find(needle, start)
        if p < 0:
            return out
        pre_ok = not first_w or p == 0 or not is_word(hay[p - 1])
        post = p + len(needle)
        post_ok = not last_w or post == len(hay) or not is_word(hay[post])
        if pre_ok and post_ok:
            out.append(p)
        start = p + 1


def comps(rel):
    return [c for c in rel.split("/") if c]


def in_dirs(rel, dirs):
    return any(c in dirs for c in comps(rel))


def is_fluid_rs(rel):
    c = comps(rel)
    return len(c) >= 2 and c[-2] == "engine" and c[-1] == "fluid.rs"


def ident_ending_at(line, end):
    e = end - 1
    while e >= 0 and line[e] in " \t":
        e -= 1
    stop = e
    while e >= 0 and is_word(line[e]):
        e -= 1
    if e == stop:
        return None
    name = line[e + 1 : stop + 1]
    if not name or name[0].isdigit() or name in ("mut", "let", "pub", "ref"):
        return None
    return name


TYPE_CHARS = set("<>,&' \t[]")


def binder_before(line, p):
    q = p - 1
    while q >= 0:
        ch = line[q]
        if ch == ":":
            if q > 0 and line[q - 1] == ":":
                q -= 2
                continue
            return ident_ending_at(line, q)
        if ch == "=":
            if q > 0 and line[q - 1] in "=<>!":
                return None
            return ident_ending_at(line, q)
        if is_word(ch) or ch in TYPE_CHARS:
            q -= 1
        else:
            return None
    return None


def hash_names(code):
    names = set()
    for line in code:
        for needle in ("HashMap", "HashSet"):
            for p in token_positions(line, needle):
                name = binder_before(line, p)
                if name:
                    names.add(name)
    return names


def parse_annotations(rel, code, com, findings):
    file_allows, line_allows = set(), {}
    for idx, comment in enumerate(com):
        lineno = idx + 1
        pos = comment.find("detlint:")
        if pos < 0:
            continue
        rest = comment[pos + len("detlint:") :].lstrip()
        if rest.startswith("allow-file("):
            file_scope, body = True, rest[len("allow-file(") :]
        elif rest.startswith("allow("):
            file_scope, body = False, rest[len("allow(") :]
        else:
            findings.append(
                (rel, lineno, "DLINT",
                 "malformed detlint annotation (expected `allow(RULE) reason` "
                 "or `allow-file(RULE) reason`): `%s`" % rest.strip())
            )
            continue
        close = body.find(")")
        if close < 0:
            findings.append(
                (rel, lineno, "DLINT", "malformed detlint annotation: missing `)`")
            )
            continue
        rule = body[:close].strip()
        if rule not in RULE_IDS:
            findings.append(
                (rel, lineno, "DLINT", "unknown rule `%s` in detlint annotation" % rule)
            )
            continue
        reason = body[close + 1 :].strip()
        if not reason:
            findings.append(
                (rel, lineno, "DLINT",
                 "detlint allow(%s) annotation requires a non-empty reason" % rule)
            )
            continue
        if file_scope:
            file_allows.add(rule)
        else:
            target = lineno
            if not code[idx].strip():
                for j in range(idx + 1, len(code)):
                    if code[j].strip():
                        target = j + 1
                        break
            line_allows.setdefault(target, set()).add(rule)
    return file_allows, line_allows


def sorted_nearby(code, idx):
    end = min(idx + D001_SORT_WINDOW + 1, len(code))
    return any(".sort" in l or "BTree" in l for l in code[idx:end])


def rule_d001(rel, code, out):
    if not in_dirs(rel, ("engine", "optimizer", "experiments")):
        return
    names = hash_names(code)
    if not names:
        return
    for idx, line in enumerate(code):
        for name in names:
            hit = False
            for p in token_positions(line, name):
                after = line[p + len(name) :]
                if any(after.startswith(m) for m in D001_METHODS):
                    hit = True
                elif not after.strip():
                    # Multiline method chain: `self.name` at end of line,
                    # `.iter()` on the next code line.
                    nxt = next((l for l in code[idx + 1 :] if l.strip()), "")
                    if any(nxt.lstrip().startswith(m) for m in D001_METHODS):
                        hit = True
            if not hit:
                for p in token_positions(line, "in"):
                    rest = line[p + 2 :].lstrip()
                    if rest.startswith("&"):
                        rest = rest[1:]
                    if rest.startswith("mut "):
                        rest = rest[4:].lstrip()
                    if rest.startswith("self."):
                        rest = rest[5:]
                    if rest.startswith(name):
                        tail = rest[len(name) :]
                        if not tail or (not is_word(tail[0]) and tail[0] not in ".("):
                            hit = True
            if hit and not sorted_nearby(code, idx):
                out.append(
                    (rel, idx + 1, "D001",
                     "iteration over hash container `%s` may leak nondeterministic "
                     "order; sort the result, use BTreeMap/BTreeSet, or annotate "
                     "`// detlint: allow(D001) <reason>`" % name)
                )


def rule_d002(rel, code, out):
    all_code = "\n".join(code)
    starts = [0]
    for i, ch in enumerate(all_code):
        if ch == "\n":
            starts.append(i + 1)

    def line_of(off):
        import bisect

        return bisect.bisect_right(starts, off)

    for opener in D002_OPENERS:
        for p in token_positions(all_code, opener):
            j = p + len(opener)
            while j < len(all_code) and all_code[j].isspace():
                j += 1
            if j >= len(all_code) or all_code[j] != "(":
                continue
            start = j
            depth = 0
            while j < len(all_code):
                if all_code[j] == "(":
                    depth += 1
                elif all_code[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            span = all_code[start:j]
            for q in token_positions(span, "partial_cmp"):
                out.append(
                    (rel, line_of(start + q), "D002",
                     "`partial_cmp` inside `%s` comparator; use `total_cmp` "
                     "for a NaN-safe total order" % opener)
                )


def rule_d003(rel, code, out):
    if not in_dirs(rel, ("engine", "model", "solver", "optimizer")):
        return
    c = comps(rel)
    if any(s == "benches" for s in c) or (c and "bench" in c[-1]):
        return
    for idx, line in enumerate(code):
        for token in ("Instant::now", "SystemTime", "std::time"):
            if token_positions(line, token):
                out.append(
                    (rel, idx + 1, "D003",
                     "wall-clock time source `%s` in the deterministic core; "
                     "use virtual time, or move timing to bench/experiment code"
                     % token)
                )
                break


def rule_d004(rel, code, out):
    for idx, line in enumerate(code):
        for token in ("thread_rng", "rand::random", "RandomState"):
            if token_positions(line, token):
                out.append(
                    (rel, idx + 1, "D004",
                     "ambient randomness `%s`; every draw must flow from an "
                     "explicit seed through util::rng::Pcg64" % token)
                )
                break


def rule_d005(rel, code, out):
    if is_fluid_rs(rel):
        return
    for idx, line in enumerate(code):
        for token in ("std::thread", "thread::spawn", ".spawn("):
            if token_positions(line, token):
                out.append(
                    (rel, idx + 1, "D005",
                     "thread creation `%s` outside engine/fluid.rs; "
                     "parallelism is confined to the sharded fluid re-solve"
                     % token)
                )
                break


def rule_d006(rel, code, com, out):
    for idx, line in enumerate(code):
        for p in token_positions(line, "+="):
            name = ident_ending_at(line, p)
            if not name or not any(name.endswith(s) for s in D006_SUFFIXES):
                continue
            lo = max(0, idx - D006_COMMENT_WINDOW)
            if any("exact" in c.lower() for c in com[lo : idx + 1]):
                continue
            out.append(
                (rel, idx + 1, "D006",
                 "`+=` into exact-conservation counter `%s` without an "
                 "adjacent `exact` comment; byte credits must stay exact "
                 "(integers carried in f64)" % name)
            )


def analyze_source(rel, text, analysis):
    code, com = mask_source(text)
    findings = []
    file_allows, line_allows = parse_annotations(rel, code, com, findings)
    candidates = []
    rule_d001(rel, code, candidates)
    rule_d002(rel, code, candidates)
    rule_d003(rel, code, candidates)
    rule_d004(rel, code, candidates)
    rule_d005(rel, code, candidates)
    rule_d006(rel, code, com, candidates)
    for f in candidates:
        _, line, rule, _ = f
        if rule in file_allows or rule in line_allows.get(line, ()):
            analysis["suppressed"] += 1
        else:
            findings.append(f)
    analysis["files"] += 1
    analysis["findings"].extend(sorted(set(findings)))


def collect_rs_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                full = os.path.join(dirpath, f)
                out.append(os.path.relpath(full, root).replace(os.sep, "/"))
    return sorted(out)


def analyze_tree(root, display_prefix, analysis):
    for rel in collect_rs_files(root):
        with open(os.path.join(root, rel), encoding="utf-8") as fh:
            text = fh.read()
        before = len(analysis["findings"])
        analyze_source(rel, text, analysis)
        if display_prefix:
            pfx = display_prefix.rstrip("/")
            analysis["findings"][before:] = [
                ("%s/%s" % (pfx, f), l, r, m)
                for (f, l, r, m) in analysis["findings"][before:]
            ]
    analysis["findings"] = sorted(set(analysis["findings"]))


def new_analysis():
    return {"files": 0, "suppressed": 0, "findings": []}


def render_json(analysis):
    return (
        json.dumps(
            {
                "version": 1,
                "files": analysis["files"],
                "suppressed": analysis["suppressed"],
                "findings": [
                    {"file": f, "line": l, "rule": r, "message": m}
                    for (f, l, r, m) in analysis["findings"]
                ],
            },
            separators=(",", ":"),
        )
        + "\n"
    )


def self_test(repo_root):
    """Pin this mirror to the fixture contract shared with the Rust
    implementation, and round-trip the JSON schema."""
    fixtures = os.path.join(repo_root, "rust/tools/detlint/tests/fixtures")
    tree = os.path.join(fixtures, "tree")
    if not os.path.isdir(tree):
        print("detlint --self-test: fixture tree missing: %s" % tree, file=sys.stderr)
        return 2
    analysis = new_analysis()
    analyze_tree(tree, "", analysis)
    got = [(f, l, r) for (f, l, r, _) in analysis["findings"]]
    expected = []
    with open(os.path.join(fixtures, "expected.txt"), encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            file_, lineno, rule = line.rsplit(":", 2)
            expected.append((file_, int(lineno), rule))
    if got != expected:
        print("detlint --self-test: fixture findings drifted from expected.txt",
              file=sys.stderr)
        for f in sorted(set(got) - set(expected)):
            print("  unexpected: %s:%d:%s" % f, file=sys.stderr)
        for f in sorted(set(expected) - set(got)):
            print("  missing:    %s:%d:%s" % f, file=sys.stderr)
        return 1
    if analysis["suppressed"] != 3:
        print("detlint --self-test: expected 3 allow-suppressed findings, got %d"
              % analysis["suppressed"], file=sys.stderr)
        return 1
    parsed = json.loads(render_json(analysis))
    assert parsed["version"] == 1 and len(parsed["findings"]) == len(expected)
    for key in ("file", "line", "rule", "message"):
        assert all(key in f for f in parsed["findings"])
    print("detlint --self-test: OK (%d fixture findings, %d suppressed)"
          % (len(expected), analysis["suppressed"]))
    return 0


def main(argv):
    json_mode = False
    selftest = False
    paths = []
    for a in argv[1:]:
        if a == "--json":
            json_mode = True
        elif a == "--self-test":
            selftest = True
        elif a in ("--help", "-h"):
            print(__doc__)
            return 0
        elif a.startswith("-"):
            print("detlint: unknown flag `%s`" % a, file=sys.stderr)
            return 2
        else:
            paths.append(a)
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if selftest:
        return self_test(repo_root)
    if not paths:
        paths = ["rust/src"]
    analysis = new_analysis()
    for p in paths:
        if os.path.isdir(p):
            analyze_tree(p, p, analysis)
        elif os.path.isfile(p):
            with open(p, encoding="utf-8") as fh:
                analyze_source(p, fh.read(), analysis)
        else:
            print("detlint: no such file or directory: `%s`" % p, file=sys.stderr)
            return 2
    analysis["findings"] = sorted(set(analysis["findings"]))
    if json_mode:
        sys.stdout.write(render_json(analysis))
    else:
        for f, l, r, m in analysis["findings"]:
            print("%s:%d: %s %s" % (f, l, r, m))
        print("detlint: %d finding(s) in %d file(s), %d suppressed by allow"
              % (len(analysis["findings"]), analysis["files"], analysis["suppressed"]))
    return 1 if analysis["findings"] else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
