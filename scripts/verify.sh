#!/usr/bin/env bash
# Tier-1 verification for the mrperf workspace: build, test, lint, and a
# CLI smoke pass. Referenced by .claude/skills/verify/SKILL.md.
#
# Usage: scripts/verify.sh [--quick]
#   --quick   skip clippy and the CLI smoke probes (build + test only)
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
[[ "${1:-}" == "--quick" ]] && QUICK=1

# Hard gate: determinism / invariant static analysis (docs/LINTS.md).
# Pure source analysis via the Python mirror of rust/tools/detlint —
# runs (and must pass) even in containers with no Rust toolchain.
echo "== detlint: self-test"
python3 scripts/detlint.py --self-test

echo "== detlint: rust/src must be lint-clean"
python3 scripts/detlint.py rust/src

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify.sh: cargo unavailable — detlint gate green, build/test/smoke skipped"
  exit 0
fi

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== tier-1: cargo test --doc"
# Module-doc examples are runnable and gated here so docs cannot rot.
cargo test --doc -q

echo "== detlint: canonical crate tests (pins Rust impl to the fixtures)"
cargo test -q -p detlint

if [[ "$QUICK" == "0" ]]; then
  if cargo clippy --version >/dev/null 2>&1; then
    echo "== lint: cargo clippy (warnings are errors)"
    cargo clippy --workspace --all-targets -- -D warnings
  else
    echo "== lint: clippy unavailable, skipped"
  fi

  echo "== smoke: CLI surface"
  BIN=./target/release/mrperf
  "$BIN" list >/dev/null
  "$BIN" plan --env 8-dc-global >/dev/null
  "$BIN" plan --gen hier-wan:64 --optimizer gradient >/dev/null
  "$BIN" run --gen hier-wan:64 --optimizer uniform >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer uniform --locality --dynamics failures:3 >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer e2e-multi --hedge 0.1 --dynamics failures:3 >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer uniform --dynamics staleness:3 >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer uniform --threads 4 >/dev/null
  # Checkpoint/crash/resume: crash mid-run, resume from the in-memory
  # snapshot, finish in one invocation; then the same through a file,
  # and a file-based resume of a fresh run.
  "$BIN" run --gen hier-wan:16 --optimizer uniform --checkpoint-every 2 --crash-at 5 >/dev/null
  CKPT="$(mktemp -t mrperf-ckpt.XXXXXX)"
  "$BIN" run --gen hier-wan:16 --optimizer uniform --checkpoint-every 2 --crash-at 5 \
    --checkpoint-path "$CKPT" >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer uniform --resume-from "$CKPT" >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer uniform --max-attempts 1 --dynamics failures:3 >/dev/null
  # Online re-optimization: event-driven and cadence policies, plus a
  # replanning run that crashes and resumes through a checkpoint.
  "$BIN" run --gen hier-wan:16 --optimizer e2e-multi --replan on-event --dynamics failures:3 >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer e2e-multi --replan every:5 --dynamics burst:3 >/dev/null
  "$BIN" run --gen hier-wan:16 --optimizer e2e-multi --replan on-event --dynamics failures:3 \
    --checkpoint-every 2 --crash-at 5 >/dev/null
  "$BIN" experiment replan --gen hier-wan:16 >/dev/null
  "$BIN" experiment resilience --gen hier-wan:16 >/dev/null
  "$BIN" experiment churn --gen hier-wan:16 --dynamics burst:7 >/dev/null
  "$BIN" experiment churn --profiles all --gen hier-wan:16 --dynamics failures:7 --hedge 0.05 >/dev/null
  "$BIN" experiment adversary --gen hier-wan:16 --seed 7 --budget 2 --restarts 2 >/dev/null
  "$BIN" experiment tenancy --gen hier-wan:16 --jobs 4 --loads 1 --policies fifo,fair-share,deadline >/dev/null
  "$BIN" experiment tenancy --gen hier-wan:16 --jobs 3 --arrivals trace:0,0,0 --policies deadline --slack 2 >/dev/null
  "$BIN" experiment tenancy --gen hier-wan:16 --jobs 4 --loads 1 --policies fair-share --threads 4 >/dev/null
  # Clean-error probes must fail (a bare `!` pipeline is exempt from
  # set -e, so check the status explicitly).
  if "$BIN" plan --gen hier-wan:3 >/dev/null 2>&1; then
    echo "FAIL: --gen hier-wan:3 should be rejected" >&2
    exit 1
  fi
  if "$BIN" plan --gen nope:64 >/dev/null 2>&1; then
    echo "FAIL: --gen nope:64 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen >/dev/null 2>&1; then
    echo "FAIL: trailing value-less --gen should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --dynamics nope:1 >/dev/null 2>&1; then
    echo "FAIL: --dynamics nope:1 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --hedge 1.5 >/dev/null 2>&1; then
    echo "FAIL: --hedge 1.5 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment churn --profiles some --gen hier-wan:16 >/dev/null 2>&1; then
    echo "FAIL: --profiles some should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment churn --gen hier-wan:16 --hedge 0.1 >/dev/null 2>&1; then
    echo "FAIL: --hedge without --profiles all should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --dynamics staleness:x >/dev/null 2>&1; then
    echo "FAIL: --dynamics staleness:x should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment adversary --gen hier-wan:16 --budget 0 >/dev/null 2>&1; then
    echo "FAIL: adversary --budget 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment adversary --gen hier-wan:16 --restarts 0 >/dev/null 2>&1; then
    echo "FAIL: adversary --restarts 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment tenancy --gen hier-wan:16 --jobs 0 >/dev/null 2>&1; then
    echo "FAIL: tenancy --jobs 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment tenancy --gen hier-wan:16 --jobs 2 --arrivals poisson:0 >/dev/null 2>&1; then
    echo "FAIL: tenancy --arrivals poisson:0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment tenancy --gen hier-wan:16 --jobs 2 --policies bogus >/dev/null 2>&1; then
    echo "FAIL: tenancy --policies bogus should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment tenancy --gen hier-wan:16 --jobs 2 --loads 0 >/dev/null 2>&1; then
    echo "FAIL: tenancy --loads 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --optimizer uniform --threads 0 >/dev/null 2>&1; then
    echo "FAIL: run --threads 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" experiment tenancy --gen hier-wan:16 --jobs 2 --threads 0 >/dev/null 2>&1; then
    echo "FAIL: tenancy --threads 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --max-attempts 0 >/dev/null 2>&1; then
    echo "FAIL: run --max-attempts 0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --crash-at 5 >/dev/null 2>&1; then
    echo "FAIL: --crash-at without --checkpoint-every should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --replan bogus >/dev/null 2>&1; then
    echo "FAIL: --replan bogus should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --replan every:0 >/dev/null 2>&1; then
    echo "FAIL: --replan every:0 should be rejected" >&2
    exit 1
  fi
  if "$BIN" run --gen hier-wan:16 --replan on-event --stealing >/dev/null 2>&1; then
    echo "FAIL: --replan with --stealing should be rejected" >&2
    exit 1
  fi
  # Snapshot reader rejections: malformed JSON, and a version from the
  # future (valid doc, unreadable by this build).
  BADSNAP="$(mktemp -t mrperf-badsnap.XXXXXX)"
  echo 'not json' > "$BADSNAP"
  if "$BIN" run --gen hier-wan:16 --resume-from "$BADSNAP" >/dev/null 2>&1; then
    echo "FAIL: malformed snapshot should be rejected" >&2
    exit 1
  fi
  sed 's/"version":1/"version":999/' "$CKPT" > "$BADSNAP"
  if "$BIN" run --gen hier-wan:16 --optimizer uniform --resume-from "$BADSNAP" >/dev/null 2>&1; then
    echo "FAIL: version-mismatched snapshot should be rejected" >&2
    exit 1
  fi
  rm -f "$CKPT" "$BADSNAP"
  echo "smoke OK"
fi

# (The golden-pin presence gate lives in .github/workflows/verify.yml,
# which runs right after this script — single-sourced there so the path
# and message cannot drift.)

echo "verify.sh: all green"
