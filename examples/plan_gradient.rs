//! The three-layer integration demo: optimize execution plans with the
//! AOT-compiled JAX/Pallas artifact (L2 smooth model + L1 kernel)
//! executed from rust via PJRT, and cross-check against the pure-rust
//! optimizers.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! make artifacts && cargo run --release --example plan_gradient
//! ```

use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{makespan, AppModel};
use mrperf::model::plan::Plan;
use mrperf::optimizer::{AlternatingLp, PlanOptimizer};
use mrperf::platform::{build_env, EnvKind};
use mrperf::runtime::ArtifactPlanner;
use mrperf::util::table::{fmt_secs, Table};

fn main() {
    let topo = build_env(EnvKind::Global8);
    let planner = match ArtifactPlanner::load(8, 8, 8) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", planner.platform());

    let mut t = Table::new(
        "plan optimization: AOT JAX/Pallas artifact (PJRT) vs pure-rust optimizers",
        &["alpha", "uniform s", "alternating-LP s", "artifact (L1/L2) s", "artifact vs uniform"],
    )
    .label_first();
    let cfg = BarrierConfig::ALL_GLOBAL;
    for &alpha in &[0.1, 1.0, 10.0] {
        let app = AppModel::new(alpha);
        let uni = makespan(&topo, app, cfg, &Plan::uniform(8, 8, 8));
        let alt = makespan(
            &topo,
            app,
            cfg,
            &AlternatingLp::default().optimize(&topo, app, cfg),
        );
        let plan = planner.optimize(&topo, app, cfg).expect("artifact optimize");
        plan.check(&topo).expect("valid plan");
        let art = makespan(&topo, app, cfg, &plan);
        assert!(art < uni, "artifact planner must beat uniform");
        t.add_row(vec![
            format!("{alpha}"),
            fmt_secs(uni),
            fmt_secs(alt),
            fmt_secs(art),
            format!("-{:.1}%", (1.0 - art / uni) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!("plan_gradient OK (python never ran: artifacts were AOT-compiled)");
}
