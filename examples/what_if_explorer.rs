//! "What-if" exploration (§1.4: the model answers what-if questions on
//! design alternatives): sweep α × barrier configurations × environments
//! and report where pipelining helps, where myopic optimization
//! backfires, and how the optimal plan shifts.
//!
//! ```sh
//! cargo run --release --example what_if_explorer
//! ```

use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{makespan, AppModel};
use mrperf::model::plan::Plan;
use mrperf::optimizer::{AlternatingLp, Myopic, PlanOptimizer};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::table::Table;

fn main() {
    // Q1: when does relaxing barriers help most? (§4.4: balanced phases.)
    let topo = build_env(EnvKind::Global8);
    let opt = AlternatingLp { random_starts: 2, ..Default::default() };
    let mut q1 = Table::new(
        "Q1 — normalized optimal makespan when pipelining one boundary (vs G-G-G)",
        &["alpha", "P-G-G", "G-P-G", "G-G-P", "P-P-P"],
    )
    .label_first();
    for &alpha in &[0.1, 1.0, 10.0] {
        let app = AppModel::new(alpha);
        let base = makespan(
            &topo,
            app,
            BarrierConfig::ALL_GLOBAL,
            &opt.optimize(&topo, app, BarrierConfig::ALL_GLOBAL),
        );
        let mut row = vec![format!("{alpha}")];
        for (_, cfg) in BarrierConfig::fig7_set().into_iter().skip(1) {
            let ms = makespan(&topo, app, cfg, &opt.optimize(&topo, app, cfg));
            row.push(format!("{:.3}", ms / base));
        }
        q1.add_row(row);
    }
    println!("{}", q1.render());

    // Q2: where does myopic optimization *hurt*? (§4.5: homogeneous envs.)
    let mut q2 = Table::new(
        "Q2 — myopic vs uniform across environments (>1.0 = myopic hurts)",
        &["env", "alpha 0.1", "alpha 1", "alpha 10"],
    )
    .label_first();
    for kind in EnvKind::all() {
        let t = build_env(kind);
        let mut row = vec![kind.label().to_string()];
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let cfg = BarrierConfig::ALL_GLOBAL;
            let uni = makespan(&t, app, cfg, &Plan::uniform(8, 8, 8));
            let myo = makespan(&t, app, cfg, &Myopic.optimize(&t, app, cfg));
            row.push(format!("{:.3}", myo / uni));
        }
        q2.add_row(row);
    }
    println!("{}", q2.render());

    // Q3: how concentrated does the optimal shuffle get as α grows?
    let mut q3 = Table::new(
        "Q3 — optimal plan concentration vs alpha (8-DC; max y_k and effective reducers)",
        &["alpha", "max y_k", "effective reducers (1/sum y²)"],
    )
    .label_first();
    for &alpha in &[0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let app = AppModel::new(alpha);
        let plan = opt.optimize(&topo, app, BarrierConfig::ALL_GLOBAL);
        let max_y = plan.y.iter().cloned().fold(0.0, f64::max);
        let eff = 1.0 / plan.y.iter().map(|v| v * v).sum::<f64>();
        q3.add_row(vec![
            format!("{alpha}"),
            format!("{max_y:.3}"),
            format!("{eff:.2}"),
        ]);
    }
    println!("{}", q3.render());
    println!("what-if exploration complete");
}
