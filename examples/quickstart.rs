//! Quickstart: model a platform, optimize a plan, predict and execute.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mrperf::apps::SyntheticApp;
use mrperf::engine::job::JobConfig;
use mrperf::engine::run_job;
use mrperf::experiments::common::synthetic_inputs;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::{evaluate, AppModel};
use mrperf::model::plan::Plan;
use mrperf::optimizer::{AlternatingLp, PlanOptimizer};
use mrperf::platform::{build_env, EnvKind};

fn main() {
    // 1. The platform: eight globally distributed data centers with
    //    measured PlanetLab bandwidths and compute rates (§4.1).
    let topo = build_env(EnvKind::Global8);
    println!(
        "platform: {} sources / {} mappers / {} reducers over {} sites",
        topo.n_sources(),
        topo.n_mappers(),
        topo.n_reducers(),
        topo.clusters.len()
    );

    // 2. The application model: expansion factor α (§2.1).
    let app = AppModel::new(1.0);
    let cfg = BarrierConfig::HADOOP; // G-P-L, Hadoop-like behaviour

    // 3. Optimize an execution plan (end-to-end, multi-phase — §2.3).
    let plan = AlternatingLp::default().optimize(&topo, app, cfg);
    let uniform = Plan::uniform(8, 8, 8);

    // 4. Predict makespans with the closed-form model (eqs 4–14).
    let opt_pred = evaluate(&topo, app, cfg, &plan);
    let uni_pred = evaluate(&topo, app, cfg, &uniform);
    println!(
        "model: optimized {:.0} s vs uniform {:.0} s ({:.0}% reduction)",
        opt_pred.makespan,
        uni_pred.makespan,
        (1.0 - opt_pred.makespan / uni_pred.makespan) * 100.0
    );

    // 5. Execute both plans on the emulated WAN engine (§3.1) with the
    //    α-controlled synthetic job (§3.2) and compare.
    let inputs = synthetic_inputs(8, 1 << 22, 42);
    let sapp = SyntheticApp::new(1.0);
    let jc = JobConfig::default();
    let m_opt = run_job(&topo, &plan, &sapp, &jc, &inputs).metrics;
    let m_uni = run_job(&topo, &uniform, &sapp, &jc, &inputs).metrics;
    println!(
        "engine: optimized {:.1} s vs uniform {:.1} s ({:.0}% reduction)",
        m_opt.makespan,
        m_uni.makespan,
        (1.0 - m_opt.makespan / m_uni.makespan) * 100.0
    );
    assert!(m_opt.makespan < m_uni.makespan, "optimized plan should win");
    println!("quickstart OK");
}
