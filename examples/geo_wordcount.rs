//! End-to-end driver (the DESIGN.md §5 headline experiment): run the
//! paper's three real applications across the eight-data-center emulated
//! PlanetLab platform, comparing uniform, vanilla-Hadoop-style, and
//! optimized execution — the Fig 9 reproduction — and print the
//! paper-vs-measured summary.
//!
//! ```sh
//! cargo run --release --example geo_wordcount
//! ```

use mrperf::engine::job::JobConfig;
use mrperf::engine::run_job;
use mrperf::experiments::fig9to12::AppKind;
use mrperf::model::barrier::BarrierConfig;
use mrperf::model::makespan::AppModel;
use mrperf::model::plan::Plan;
use mrperf::optimizer::{AlternatingLp, PlanOptimizer};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::table::{fmt_pct, fmt_secs, Table};

fn main() {
    let topo = build_env(EnvKind::Global8);
    let mut t = Table::new(
        "geo-distributed MapReduce: three applications, three execution strategies",
        &["app", "alpha", "uniform s", "hadoop s", "optimized s", "opt vs hadoop", "paper"],
    )
    .label_first();

    // Paper's reported improvements of optimized over vanilla Hadoop.
    let paper = [("Word Count", "36%"), ("Sessionization", "41%"), ("Full Inverted Index", "31%")];

    for kind in AppKind::all() {
        // Profile α from a sample (the paper's methodology, §2.1).
        let alpha = kind.profiled_alpha();
        let app = kind.app();
        let inputs = kind.inputs(8, 1 << 21, 0xE2E);

        // Uniform plan, statically enforced.
        let uniform = Plan::uniform(8, 8, 8);
        let m_uni = run_job(&topo, &uniform, app.as_ref(), &JobConfig::optimized(), &inputs);

        // Vanilla Hadoop: locality push + uniform shuffle + dynamics.
        let hadoop_plan = Plan::local_push(&topo);
        let m_had = run_job(
            &topo,
            &hadoop_plan,
            app.as_ref(),
            &JobConfig::vanilla_hadoop(),
            &inputs,
        );

        // Our optimized plan (end-to-end multi-phase, G-P-L model).
        let plan = AlternatingLp::default().optimize(
            &topo,
            AppModel::new(alpha),
            BarrierConfig::HADOOP,
        );
        let m_opt = run_job(&topo, &plan, app.as_ref(), &JobConfig::optimized(), &inputs);

        let uni = m_uni.metrics.makespan;
        let had = m_had.metrics.makespan;
        let opt = m_opt.metrics.makespan;
        let label = kind.label();
        let paper_gain = paper.iter().find(|(k, _)| *k == label).map(|(_, v)| *v).unwrap();
        t.add_row(vec![
            label.into(),
            format!("{alpha:.2}"),
            fmt_secs(uni),
            fmt_secs(had),
            fmt_secs(opt),
            format!("-{}", fmt_pct(1.0 - opt / had)),
            format!("-{paper_gain}"),
        ]);

        // Sanity: every strategy produced identical application output
        // volume (the plans only move *where* work happens).
        assert_eq!(m_uni.metrics.output_records, m_opt.metrics.output_records);
        assert_eq!(m_had.metrics.output_records, m_opt.metrics.output_records);
    }
    println!("{}", t.render());
    println!("(paper column: reported reduction of optimized vs vanilla Hadoop, §4.6.3)");
}
